"""Mapping resolution (TeAAL Sections 2.3, 3.2).

Turns the declarative mapping spec into an executable plan per Einsum:

  * applies partitioning directives (uniform_shape / uniform_occupancy /
    flatten) to every participating tensor, with leader-follower
    boundary adoption;
  * establishes the partitioned rank-name registry (K split twice ->
    K2, K1, K0; flatten (M, K0) -> MK0; ...) and the rank -> index-var
    correspondence;
  * resolves the loop order (default: output ranks then reduced ranks);
  * infers rank swizzles for concordant traversal (Sec. 3.2.2): inputs
    are swizzled to the loop order restricted to their ranks; outputs
    are built concordant with the loop order and swizzled back to their
    declared rank-order afterwards.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .einsum import Einsum, TensorAccess
from .fibertree import FTensor
from .spec import (AcceleratorSpec, Directive, EinsumMapping, Flatten,
                   MappingSpec, UniformOccupancy, UniformShape)


@dataclass
class RankInfo:
    """One loop rank: its name and the index vars it binds (if innermost)."""
    name: str
    vars: Tuple[str, ...]          # original index vars this rank spans
    binds: bool                    # True if this rank binds its vars
    #                                (innermost partition level)
    flattened: bool = False        # coordinates are tuples


@dataclass
class TensorPlan:
    """Per-tensor, per-Einsum transformation plan."""
    name: str
    declared_order: List[str]       # storage rank-order (mapping spec)
    exec_order: List[str]           # concordant order used in the loop nest
    partitioned: bool = False
    swizzled_online: bool = False   # intermediate swizzle (merger work)


@dataclass
class EinsumPlan:
    einsum: Einsum
    loop_order: List[RankInfo]
    tensors: Dict[str, TensorPlan]
    space_ranks: List[str]
    time_ranks: List[str]
    output: str
    # partition-created rank names: name -> 'upper' | 'innermost' | 'flat'
    created_ranks: Dict[str, str] = field(default_factory=dict)
    # rank name -> index vars it spans
    var_map: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    # tensor -> partitioning keys that apply to it (leader-follower aware)
    applied: Dict[str, List] = field(default_factory=dict)
    # tensor -> ordered transform steps with sizes resolved, so backends
    # that hold tensors in columnar (CSF) form can run the Sec. 3.2
    # pre-pass themselves without the spec/resolver in hand.  Steps:
    #   ("flatten", (rank, ...))                 flatten a rank group
    #   ("split", rank, ((kind, size, leader), ...))  top-down splits,
    #        kind in {"shape", "occupancy"}; leader None for shape
    transform_recipe: Dict[str, List[Tuple]] = field(default_factory=dict)

    @property
    def spatial_fanout_ranks(self) -> List[str]:
        return self.space_ranks


class MappingResolver:
    """Resolves a full AcceleratorSpec into per-Einsum plans and
    transformed fibertrees."""

    def __init__(self, spec: AcceleratorSpec,
                 params: Optional[Dict[str, int]] = None):
        self.spec = spec
        self.params = params or {}
        # registry: rank name -> tuple of original index vars
        self.var_map: Dict[str, Tuple[str, ...]] = {}
        for tensor, ranks in spec.einsum.declaration.items():
            for r in ranks:
                self.var_map.setdefault(r, (r.lower(),))

    # ------------------------------------------------------------------ #
    def _resolve_size(self, size: Union[int, str]) -> int:
        if isinstance(size, int):
            return size
        if size in self.params:
            return int(self.params[size])
        raise KeyError(f"unresolved symbolic partition size {size!r} "
                       f"(params: {sorted(self.params)})")

    # ------------------------------------------------------------------ #
    def plan(self, out_name: str) -> EinsumPlan:
        """Build the EinsumPlan (no tensor data needed)."""
        einsum = self.spec.einsum.einsum_for(out_name)
        em = self.spec.mapping.einsum_mapping(out_name)
        decl = self.spec.einsum.declaration

        # ---- simulate partitioning on rank *names* to build the registry
        # tensor -> current rank list (names)
        cur: Dict[str, List[str]] = {}
        for t in set([out_name] + einsum.input_names):
            order = self.spec.mapping.rank_order.get(t) or decl.get(t) or []
            cur[t] = list(order)

        partitioned_tensors: Dict[str, bool] = {t: False for t in cur}
        created: Dict[str, str] = {}
        applied: Dict[str, List] = {t: [] for t in cur}
        recipe: Dict[str, List[Tuple]] = {t: [] for t in cur}
        for key, directives in em.partitioning.items():
            if isinstance(key, tuple):
                # flatten group
                assert any(isinstance(dv, Flatten) for dv in directives)
                new_name = "".join(key)
                self.var_map[new_name] = tuple(
                    v for r in key for v in self.var_map[r])
                created[new_name] = "flat"
                for t, ranks in cur.items():
                    if all(r in ranks for r in key):
                        i = min(ranks.index(r) for r in key)
                        # ranks must be adjacent in-order after swizzle;
                        # we reorder names here (swizzle applied on data)
                        for r in key:
                            ranks.remove(r)
                        ranks[i:i] = [new_name]
                        partitioned_tensors[t] = True
                        applied[t].append(key)
                        recipe[t].append(("flatten", tuple(key)))
            else:
                n = len([dv for dv in directives
                         if not isinstance(dv, Flatten)])
                if n == 0:
                    continue
                new_names = [f"{key}{i}" for i in range(n, -1, -1)]
                for nm in new_names:
                    self.var_map[nm] = self.var_map[key]
                    created[nm] = "innermost" if nm.endswith("0") \
                        and nm == new_names[-1] else "upper"
                # snapshot: applicability must be judged against the state
                # before *any* tensor is split at this key (the leader may
                # come first in dict order and be renamed mid-pass)
                pre = {t: list(r) for t, r in cur.items()}
                split_steps = tuple(
                    ("shape", self._resolve_size(d.size), None)
                    if isinstance(d, UniformShape)
                    else ("occupancy", d.size, d.leader)
                    for d in directives if not isinstance(d, Flatten))
                for t, ranks in cur.items():
                    if key in ranks and self._partition_applies(
                            t, key, directives, pre):
                        i = ranks.index(key)
                        ranks[i:i + 1] = new_names
                        partitioned_tensors[t] = True
                        applied[t].append(key)
                        recipe[t].append(("split", key, split_steps))

        # ---- loop order
        if em.loop_order:
            loop_names = list(em.loop_order)
        else:
            # default: the output's ranks, then one rank per reduced index
            # var.  The iteration space is over the Einsum's index vars --
            # ranks that bind no einsum var (e.g. I's W in T[q,s]=I[q+s])
            # are accessed by affine lookup, never looped.
            out_ranks = list(cur[out_name])
            covered = {v for r in out_ranks
                       for v in self.var_map.get(r, (r.lower(),))}
            red_vars = [v for v in einsum.all_vars if v not in covered]
            red: List[str] = []
            for t in einsum.input_names:
                for r in cur[t]:
                    vars_ = self.var_map.get(r, (r.lower(),))
                    if (r not in red and r not in out_ranks
                            and vars_ and all(v in red_vars for v in vars_)):
                        red.append(r)
                        covered.update(vars_)
            for v in red_vars:
                if v not in covered:           # purely-affine var: synthesize
                    name = v.upper()
                    self.var_map.setdefault(name, (v,))
                    red.append(name)
                    covered.add(v)
            loop_names = out_ranks + red

        # strip annotations such as 'N.coord' (SIGMA spacetime syntax)
        def strip(r: str) -> str:
            return r.split(".")[0]

        loop_names = [strip(r) for r in loop_names]

        # which loop rank binds each var: the *last* rank in loop order
        # whose var-set covers the var
        binds_at: Dict[str, int] = {}
        for i, r in enumerate(loop_names):
            for v in self.var_map.get(r, ()):
                binds_at[v] = i
        loop: List[RankInfo] = []
        for i, r in enumerate(loop_names):
            vars_ = self.var_map.get(r, (r.lower(),))
            loop.append(RankInfo(
                name=r, vars=vars_,
                binds=all(binds_at.get(v) == i for v in vars_),
                flattened=len(vars_) > 1))

        # ---- per-tensor execution orders (concordant with loop order)
        # A rank that matches a loop name sits at that loop level; a rank
        # accessed by lookup sits just after the loop level where its index
        # vars are all bound (so catch-up descents stay concordant).
        def _level_key(rank: str):
            if rank in loop_names:
                return (loop_names.index(rank), 0)
            vars_ = self.var_map.get(rank, (rank.lower(),))
            lvl = max((binds_at.get(v, len(loop_names)) for v in vars_),
                      default=len(loop_names))
            return (lvl, 1)

        tensors: Dict[str, TensorPlan] = {}
        for t, ranks in cur.items():
            exec_order = sorted(ranks, key=_level_key)  # stable
            declared = self.spec.mapping.rank_order.get(t) or decl.get(t) or []
            tensors[t] = TensorPlan(
                name=t, declared_order=list(declared),
                exec_order=exec_order,
                partitioned=partitioned_tensors[t],
                swizzled_online=(t in self.spec.einsum.cascade_outputs
                                 and t != out_name))

        st = em.spacetime
        space = [strip(r) for r in (st.space if st else [])]
        time = [strip(r) for r in (st.time if st else loop_names)]
        return EinsumPlan(einsum=einsum, loop_order=loop, tensors=tensors,
                          space_ranks=space, time_ranks=time,
                          output=out_name, created_ranks=created,
                          var_map=dict(self.var_map), applied=applied,
                          transform_recipe=recipe)

    def _partition_applies(self, t: str, key: str, directives,
                           cur: Dict[str, List[str]]) -> bool:
        """A partitioning of ``key`` applies to tensor ``t`` unless an
        occupancy directive's leader has parent ranks (above ``key``) that
        ``t`` does not share.  In that case the leader's boundaries are
        per-parent-fiber and cannot be adopted statically by ``t``; the
        tensor stays unpartitioned and is accessed by coordinate lookup
        (e.g. Gamma's B, fetched row-by-row at bound k)."""
        for d in directives:
            if not isinstance(d, UniformOccupancy):
                continue
            if d.leader == t or d.leader not in cur:
                continue
            lranks = cur[d.leader]
            base = key if key in lranks else key + "0"
            if base not in lranks:
                continue
            above_leader = lranks[: lranks.index(base)]
            t_ranks = cur[t]
            tbase = key if key in t_ranks else key + "0"
            above_t = t_ranks[: t_ranks.index(tbase)] if tbase in t_ranks \
                else t_ranks
            for lr in above_leader:
                # strip partition suffixes when comparing base ranks
                lr_base = lr.rstrip("0123456789")
                if not any(r.rstrip("0123456789") == lr_base
                           for r in above_t):
                    return False
        return True

    # ------------------------------------------------------------------ #
    def transform_tensor(self, out_name: str, ft: FTensor) -> FTensor:
        """Apply this Einsum's partitioning + swizzle to one input tensor,
        returning the concordant execution-form fibertree."""
        em = self.spec.mapping.einsum_mapping(out_name)
        plan = self.plan(out_name)
        t = ft.name
        if t not in plan.tensors:
            return ft
        cur = ft

        applied_keys = plan.applied.get(t, [])
        for key, directives in em.partitioning.items():
            if key not in applied_keys:
                continue
            if isinstance(key, tuple):
                if not all(r in cur.ranks for r in key):
                    continue
                # make the group adjacent & ordered, then flatten pairwise
                others = [r for r in cur.ranks if r not in key]
                idx = min(cur.ranks.index(r) for r in key)
                new_order = others[:idx] + list(key) + others[idx:]
                cur = cur.swizzle(new_order)
                name_acc = key[0]
                for r in key[1:]:
                    cur = cur.flatten_ranks(name_acc, r)
                    name_acc = name_acc + r
            else:
                if key not in cur.ranks:
                    continue
                dirs = [d for d in directives if not isinstance(d, Flatten)]
                n = len(dirs)
                if n == 0:
                    continue
                # apply top-down: each directive splits the innermost segment
                seg = key
                produced: List[str] = []  # upper ranks created so far
                for d in dirs:
                    cur = self._apply_directive(cur, seg, d, out_name)
                    upper, lower = seg + "1", seg + "0"
                    produced.append(upper)
                    seg = lower
                # rename produced + final segment to K{n}..K0
                final_names = [f"{key}{i}" for i in range(n, 0, -1)] + [f"{key}0"]
                rename = dict(zip(produced + [seg], final_names))
                cur = cur.rename_ranks(rename)

        exec_order = plan.tensors[t].exec_order
        if cur.ranks != exec_order:
            cur = cur.swizzle(exec_order)
        return cur

    def _apply_directive(self, ft: FTensor, rank: str, d: Directive,
                         out_name: str) -> FTensor:
        if isinstance(d, UniformShape):
            return ft.partition_uniform_shape(rank, self._resolve_size(d.size))
        if isinstance(d, UniformOccupancy):
            leader = self._leaders.get((out_name, d.leader)) \
                if hasattr(self, "_leaders") else None
            if leader is not None and leader.name != ft.name:
                lrank = self._leader_rank(leader, rank)
                return ft.partition_uniform_occupancy(
                    rank, d.size, leader=leader, leader_rank=lrank)
            return ft.partition_uniform_occupancy(rank, d.size)
        raise TypeError(d)

    @staticmethod
    def _leader_rank(leader: FTensor, rank: str) -> str:
        # the leader may have already been partitioned; boundaries for the
        # follower's rank R come from the leader's R (pre-partitioned form)
        return rank

    # ------------------------------------------------------------------ #
    def transform_all(self, out_name: str,
                      tensors: Dict[str, FTensor]) -> Dict[str, FTensor]:
        """Transform every input tensor of an Einsum, honoring
        leader-follower occupancy adoption (leaders transformed first,
        and their *pre-swizzle* partitioned forms provide boundaries)."""
        em = self.spec.mapping.einsum_mapping(out_name)
        plan = self.plan(out_name)
        # leaders referenced by occupancy directives
        leader_names = {d.leader for dirs in em.partitioning.values()
                        for d in dirs if isinstance(d, UniformOccupancy)}
        self._leaders: Dict[Tuple[str, str], FTensor] = {}
        out: Dict[str, FTensor] = {}
        order = ([t for t in plan.tensors if t in leader_names]
                 + [t for t in plan.tensors if t not in leader_names])
        for t in order:
            if t not in tensors:
                continue
            ft = tensors[t]
            # leaders partition by their own occupancy; register the raw
            # (unpartitioned) form so followers can adopt boundaries
            if t in leader_names:
                self._leaders[(out_name, t)] = ft
            out[t] = self.transform_tensor(out_name, ft)
        self._leaders = {}
        return out
