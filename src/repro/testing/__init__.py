"""Deterministic test harnesses (fault injection, chaos tooling)."""
from .faults import (FaultInjector, FaultSpec, InjectedFault,
                     InjectedTransientFault, SimulatedCrash,
                     active_injector, clear_injector, install_injector,
                     parse_faults)

__all__ = [
    "FaultInjector", "FaultSpec", "InjectedFault",
    "InjectedTransientFault", "SimulatedCrash", "active_injector",
    "clear_injector", "install_injector", "parse_faults",
]
