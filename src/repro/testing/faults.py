"""Deterministic fault injection for the resilient execution layer.

Every degradation path in the stack -- seam-level kernel downgrades
(``kernels/backends.py``), per-Einsum isolation (``core/vectorized.py``)
and sweep-level timeouts / retries / checkpoint-resume
(``dse/engine.py``) -- is *provoked and asserted* through this harness
rather than just believed.  An injector holds an ordered list of
``FaultSpec``s; the guarded kernel dispatcher and the sweep engine call
its hooks at well-defined instants:

  * ``before_seam(seam, backend)``  -- may raise (simulated backend
    fault: generic, transient, device-absent, i32-window overflow) or
    sleep;
  * ``after_seam(seam, backend, out)`` -- may corrupt the seam output
    (NaN/inf poisoning of reductions, out-of-range positions) so the
    guard postconditions have something real to catch;
  * ``before_point(label)``         -- sweep-engine hook: may delay a
    point (provoking the wall-clock timeout), raise (a failing design
    point) or raise ``SimulatedCrash`` (a ``BaseException`` that tears
    the whole sweep down mid-flight for checkpoint-resume tests).

Faults are deterministic: ``at=N`` fires on the N-th *matching* call
(1-based), ``times=K`` keeps firing for K consecutive matches,
``every=K`` re-fires periodically, and probabilistic injection (``p=``)
draws from a seeded generator, so a failing chaos run replays exactly.

Selection comes from an explicitly installed injector
(``install_injector``) or, when none is installed, from the
``REPRO_FAULTS`` environment variable -- semicolon-separated specs of
comma-separated ``key=value`` pairs::

    REPRO_FAULTS='seam=intersect_keys,backend=jax-jit,kind=raise,at=1'
    REPRO_FAULTS='seam=*,kind=raise,every=7;seam=segmented_reduce,kind=nan,at=2'

Accounting: the injector counts every fault it fires at a seam; the
guarded dispatcher counts every ``DowngradeEvent`` it records.  A chaos
run fails when a seam fault fired without a recorded event -- that is
the definition of a *silent* downgrade (``verify_no_silent_downgrades``).
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

ENV_VAR = "REPRO_FAULTS"

#: fault kinds an injector understands (see FaultSpec.kind)
FAULT_KINDS = ("raise", "transient", "device-absent", "i32-overflow",
               "nan", "corrupt-pos", "delay", "point-error",
               "point-delay", "crash")

#: hook names FaultSpec.seam may match ('*' matches any seam)
SEAMS = ("intersect_keys", "union_keys", "union_k_keys", "lookup_keys",
         "segmented_reduce")


# ---------------------------------------------------------------------- #
# injected exception types
# ---------------------------------------------------------------------- #
class InjectedFault(RuntimeError):
    """A deterministic, injected backend fault (classified permanent by
    the guard: the seam downgrades without retrying)."""


class InjectedTransientFault(InjectedFault):
    """An injected *transient* fault: the guard retries the same
    backend with backoff before downgrading."""


class InjectedDeviceAbsent(InjectedFault):
    """Simulates a missing / lost accelerator device."""


class InjectedI32Overflow(InjectedFault):
    """Simulates a key domain blowing the Pallas i32 admissibility
    window at kernel time (past the host-side pre-checks)."""


class SimulatedCrash(BaseException):
    """Tears down a sweep mid-flight.  Deliberately *not* an
    ``Exception``: per-point isolation must not absorb it, exactly like
    a SIGKILL / OOM would not be absorbed."""


_RAISES = {
    "raise": InjectedFault,
    "transient": InjectedTransientFault,
    "device-absent": InjectedDeviceAbsent,
    "i32-overflow": InjectedI32Overflow,
}


# ---------------------------------------------------------------------- #
# fault specs
# ---------------------------------------------------------------------- #
@dataclass
class FaultSpec:
    """One deterministic fault rule.

    ``at`` fires on the N-th matching call (1-based, 0 = disabled
    unless ``p`` or ``every`` is set); ``times`` keeps it firing for
    that many consecutive matches; ``every`` re-fires on every K-th
    matching call after the first firing; ``p`` fires probabilistically
    from the injector's seeded generator."""
    kind: str = "raise"
    seam: str = "*"                  # seam name or '*' (seam faults)
    backend: str = "*"               # kernel-backend name or '*'
    point: str = "*"                 # sweep point-label substring or '*'
    at: int = 1
    times: int = 1
    every: int = 0
    p: float = 0.0
    delay_s: float = 0.0
    # runtime state
    calls: int = 0
    fired: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")

    # -------------------------------------------------------------- #
    def _matches(self, seam: Optional[str], backend: Optional[str],
                 point: Optional[str]) -> bool:
        if seam is not None:
            if self.kind in ("point-error", "point-delay", "crash"):
                return False
            if self.seam not in ("*", seam):
                return False
            if backend is not None and self.backend not in ("*", backend):
                return False
            return True
        # sweep-point hook
        if self.kind not in ("point-error", "point-delay", "crash"):
            return False
        return self.point == "*" or (point is not None
                                     and self.point in point)

    def _should_fire(self, rng: np.random.Generator) -> bool:
        self.calls += 1
        if self.p > 0.0:
            return bool(rng.random() < self.p)
        if self.at <= 0:
            return False
        if self.calls < self.at:
            return False
        if self.calls < self.at + self.times:
            return True
        if self.every > 0:
            return (self.calls - self.at) % self.every == 0
        return False


def parse_faults(text: str) -> List[FaultSpec]:
    """Parse a ``REPRO_FAULTS`` string into FaultSpecs."""
    specs: List[FaultSpec] = []
    for chunk in text.split(";"):
        chunk = chunk.strip()
        if not chunk:
            continue
        kw: Dict[str, object] = {}
        for pair in chunk.split(","):
            if "=" not in pair:
                raise ValueError(
                    f"bad fault spec {chunk!r}: expected key=value pairs")
            k, v = pair.split("=", 1)
            k = k.strip().replace("-", "_")
            v = v.strip()
            if k in ("at", "times", "every"):
                kw[k] = int(v)
            elif k in ("p", "delay_s"):
                kw[k] = float(v)
            elif k in ("kind", "seam", "backend", "point"):
                kw[k] = v
            else:
                raise ValueError(f"unknown fault-spec key {k!r} in {chunk!r}")
        specs.append(FaultSpec(**kw))
    return specs


# ---------------------------------------------------------------------- #
# the injector
# ---------------------------------------------------------------------- #
@dataclass
class FaultInjector:
    """Holds fault rules plus deterministic firing state.  Thread-safe:
    sweep engines evaluate points from worker threads."""
    specs: List[FaultSpec] = field(default_factory=list)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()
        #: seam faults fired (raises + corruptions) -- the number the
        #: guarded dispatcher's recorded events must cover
        self.seam_faults_fired = 0
        #: sweep-point faults fired (errors + delays + crashes)
        self.point_faults_fired = 0

    # -------------------------------------------------------------- #
    def before_seam(self, seam: str, backend: str) -> None:
        """Called by the guarded dispatcher before each seam call on
        each backend; raises / sleeps per the matching specs."""
        with self._lock:
            for sp in self.specs:
                if not sp._matches(seam, backend, None):
                    continue
                if sp.kind in ("nan", "corrupt-pos"):
                    continue                   # output hooks, not input
                if not sp._should_fire(self._rng):
                    continue
                sp.fired += 1
                if sp.kind == "delay":
                    time.sleep(sp.delay_s)
                    continue
                self.seam_faults_fired += 1
                raise _RAISES[sp.kind](
                    f"injected {sp.kind} fault at {seam}/{backend} "
                    f"(call {sp.calls})")

    def after_seam(self, seam: str, backend: str, out):
        """Output-corruption hook: returns ``out`` possibly poisoned.
        The corruption is intentionally detectable by the guard
        postconditions (NaN in a reduction, out-of-range position)."""
        with self._lock:
            for sp in self.specs:
                if sp.kind not in ("nan", "corrupt-pos"):
                    continue
                if not sp._matches(seam, backend, None):
                    continue
                if not sp._should_fire(self._rng):
                    continue
                sp.fired += 1
                self.seam_faults_fired += 1
                out = _corrupt(seam, out, sp.kind)
        return out

    def before_point(self, label: str) -> None:
        """Sweep-engine hook, called once per evaluation attempt."""
        with self._lock:
            todo = []
            for sp in self.specs:
                if not sp._matches(None, None, label):
                    continue
                if not sp._should_fire(self._rng):
                    continue
                sp.fired += 1
                self.point_faults_fired += 1
                todo.append(sp)
        # act outside the lock: delays must not serialize other threads
        for sp in todo:
            if sp.kind == "point-delay":
                time.sleep(sp.delay_s)
            elif sp.kind == "crash":
                raise SimulatedCrash(
                    f"injected sweep crash at point {label!r}")
            else:
                raise InjectedFault(
                    f"injected point failure at {label!r}")

    # -------------------------------------------------------------- #
    def reset(self) -> None:
        with self._lock:
            for sp in self.specs:
                sp.calls = sp.fired = 0
            self.seam_faults_fired = 0
            self.point_faults_fired = 0
            self._rng = np.random.default_rng(self.seed)


def _corrupt(seam: str, out, kind: str):
    """Poison a seam output in a way the guard postconditions detect."""
    if seam == "segmented_reduce":
        arr = np.array(out, dtype=np.float64, copy=True)
        if len(arr):
            arr[0] = np.nan if kind == "nan" else np.inf
            return arr
        return out
    if seam in ("union_keys", "union_k_keys"):
        u, pos = (out[0], list(out[1:])) if seam == "union_keys" \
            else (out[0], out[1])
        u = np.array(u, copy=True)
        if len(u) > 1:
            u[0], u[-1] = u[-1], u[0]          # break sortedness
        return (u, *pos) if seam == "union_keys" else (u, pos)
    # position seams: out-of-range index
    arr = np.array(out, copy=True)
    if len(arr):
        arr[0] = (1 << 62)
    return arr


# ---------------------------------------------------------------------- #
# process-wide installation
# ---------------------------------------------------------------------- #
_EXPLICIT: Optional[FaultInjector] = None
_ENV_TEXT: Optional[str] = None
_ENV_INJ: Optional[FaultInjector] = None


def install_injector(inj: Optional[FaultInjector]) -> Optional[FaultInjector]:
    """Install ``inj`` as the process-wide injector (wins over
    ``$REPRO_FAULTS``; None clears)."""
    global _EXPLICIT
    _EXPLICIT = inj
    return inj


def clear_injector() -> None:
    global _EXPLICIT, _ENV_TEXT, _ENV_INJ
    _EXPLICIT = None
    _ENV_TEXT = None
    _ENV_INJ = None


def active_injector() -> Optional[FaultInjector]:
    """The explicitly installed injector, else one parsed from
    ``$REPRO_FAULTS`` (re-parsed when the variable changes), else
    None."""
    global _ENV_TEXT, _ENV_INJ
    if _EXPLICIT is not None:
        return _EXPLICIT
    text = os.environ.get(ENV_VAR, "")
    if not text:
        _ENV_TEXT, _ENV_INJ = None, None
        return None
    if text != _ENV_TEXT:
        _ENV_TEXT = text
        _ENV_INJ = FaultInjector(parse_faults(text),
                                 seed=int(os.environ.get(
                                     "REPRO_FAULTS_SEED", "0")))
    return _ENV_INJ


def verify_no_silent_downgrades() -> None:
    """Chaos-run gate: every seam fault the active injector fired must
    be covered by a recorded ``DowngradeEvent`` (see
    ``kernels.backends.events_recorded``).  Raises AssertionError on a
    silent downgrade."""
    inj = active_injector()
    if inj is None or inj.seam_faults_fired == 0:
        return
    from repro.kernels import backends as kbk
    recorded = kbk.events_recorded()
    assert recorded >= inj.seam_faults_fired, (
        f"silent downgrade: injector fired {inj.seam_faults_fired} seam "
        f"fault(s) but only {recorded} DowngradeEvent(s) were recorded")
