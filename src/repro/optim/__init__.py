from repro.optim.optimizers import (adafactor, adamw, OptState, Optimizer,
                                    clip_by_global_norm, cosine_schedule)

__all__ = ["adafactor", "adamw", "OptState", "Optimizer",
           "clip_by_global_norm", "cosine_schedule"]
