"""Optimizers: AdamW (fp32 states) and Adafactor (factored second
moment, no separate master copy) -- pure-pytree implementations.

Optimizer states inherit each parameter's PartitionSpec (ZeRO-style:
states live wherever the param shard lives, so a fully-sharded param
implies fully-sharded states).  Adafactor is selected for the >100 B
configs (grok, jamba) where AdamW's 16 B/param states cannot fit the
per-device HBM budget at 256 chips (napkin math in DESIGN.md).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
OptState = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], OptState]
    update: Callable[[Params, Params, OptState, jnp.ndarray],
                     Tuple[Params, OptState]]
    name: str = "opt"


# ---------------------------------------------------------------------- #
# gradient utilities
# ---------------------------------------------------------------------- #
def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------- #
# AdamW
# ---------------------------------------------------------------------- #
def adamw(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            # fp32 master copy
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params),
        }

    def update(params, grads, state, _loss):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p_master, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            new = p_master - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                                     + weight_decay * p_master)
            return new, m, v

        flat_m, tdef = jax.tree_util.tree_flatten(state["m"])
        flat_v = jax.tree_util.tree_leaves(state["v"])
        flat_ma = jax.tree_util.tree_leaves(state["master"])
        flat_g = jax.tree_util.tree_leaves(grads)
        outs = [upd(pm, g, m, v)
                for pm, g, m, v in zip(flat_ma, flat_g, flat_m, flat_v)]
        new_master = tdef.unflatten([o[0] for o in outs])
        new_m = tdef.unflatten([o[1] for o in outs])
        new_v = tdef.unflatten([o[2] for o in outs])
        new_params = jax.tree_util.tree_map(
            lambda nm, p: nm.astype(p.dtype), new_master, params)
        return new_params, {"step": step, "m": new_m, "v": new_v,
                            "master": new_master}

    return Optimizer(init=init, update=update, name="adamw")


# ---------------------------------------------------------------------- #
# Adafactor (factored v, first moment optional, no master copy)
# ---------------------------------------------------------------------- #
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
              decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr))

    def init(params):
        def per_param(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree_util.tree_map(per_param, params,
                                            is_leaf=lambda x: hasattr(
                                                x, "shape"))}

    def update(params, grads, state, _loss):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        beta = 1.0 - t ** (-decay)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    (vr / jnp.mean(vr, axis=-1, keepdims=True))[..., None]
                    * vc[..., None, :])
                u = g / jnp.maximum(denom, 1e-30)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g / jnp.sqrt(nv["v"])
            # update clipping (Adafactor's RMS rule)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            new = pf - lr_t * (u + weight_decay * pf)
            return new.astype(p.dtype), nv

        leaves_p, tdef = jax.tree_util.tree_flatten(params)
        leaves_g = jax.tree_util.tree_leaves(grads)
        leaves_v = tdef.flatten_up_to(state["v"])
        outs = [upd(p, g, v)
                for p, g, v in zip(leaves_p, leaves_g, leaves_v)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_v = tdef.unflatten([o[1] for o in outs])
        return new_params, {"step": step, "v": new_v}

    return Optimizer(init=init, update=update, name="adafactor")


def for_config(cfg, base_lr: float = 3e-4, warmup: int = 2000,
               total: int = 100_000) -> Optimizer:
    """AdamW below ~100 B params, Adafactor above (HBM budget)."""
    from repro.configs.base import param_count
    sched = cosine_schedule(base_lr, warmup, total)
    if param_count(cfg) > 1e11:
        return adafactor(sched)
    return adamw(sched)
