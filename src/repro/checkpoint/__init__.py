from repro.checkpoint.store import (CheckpointManager, restore_resharded,
                                    save_checkpoint, load_checkpoint)

__all__ = ["CheckpointManager", "restore_resharded", "save_checkpoint",
           "load_checkpoint"]
