"""Atomic, async-capable checkpointing with elastic restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json       pytree structure + leaf shapes/dtypes + meta
        leaf_00000.npy ...  one file per leaf (streams well to blob stores)
    <dir>/step_000123.tmp/  staging dir, atomically renamed on success
    <dir>/LATEST            text file naming the newest complete step

Fault-tolerance properties:
  * atomic publish: a crash mid-write leaves only a .tmp dir, never a
    half-visible checkpoint; LATEST is written after the rename;
  * async save: ``save_async`` snapshots device arrays to host then
    writes on a worker thread, so the train loop resumes immediately;
  * elastic restore: ``restore_resharded`` re-lays-out leaves onto any
    new mesh/sharding (the checkpoint stores the GLOBAL logical array);
  * retention: keep the newest ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Params = Any


def _flatten_with_paths(tree: Params):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save_checkpoint(directory: str | Path, step: int, tree: Params,
                    extra_meta: Optional[Dict[str, Any]] = None) -> Path:
    """Synchronous atomic save of the GLOBAL pytree."""
    directory = Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, paths, treedef = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "paths": paths,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "treedef": str(treedef),
        "meta": extra_meta or {},
        "time": time.time(),
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or "bfloat16" in str(arr.dtype):
            # numpy cannot round-trip ml_dtypes (bf16); widen to f32
            # (exact) and restore from the manifest dtype on load
            arr = arr.astype(np.float32)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    (directory / "LATEST").write_text(final.name)
    return final


def load_manifest(directory: str | Path,
                  step: Optional[int] = None) -> Dict[str, Any]:
    """The manifest dict of a checkpoint (latest by default) without
    touching the leaves.  Lets a consumer that stored its structure in
    ``extra_meta`` (e.g. sweep checkpoints: point labels, error
    strings) rebuild a ``like`` pytree before calling
    :func:`load_checkpoint`."""
    directory = Path(directory)
    if step is None:
        latest = (directory / "LATEST").read_text().strip()
        path = directory / latest
    else:
        path = directory / f"step_{step:09d}"
    return json.loads((path / "manifest.json").read_text())


def load_checkpoint(directory: str | Path, step: Optional[int] = None,
                    like: Optional[Params] = None) -> Tuple[Params, int]:
    """Load a checkpoint as host numpy arrays, re-built into the
    structure of ``like`` (required -- treedefs are not serialized
    executably, by design)."""
    directory = Path(directory)
    if step is None:
        latest = (directory / "LATEST").read_text().strip()
        path = directory / latest
    else:
        path = directory / f"step_{step:09d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves = []
    for i in range(manifest["n_leaves"]):
        arr = np.load(path / f"leaf_{i:05d}.npy")
        want = manifest["dtypes"][i]
        if "bfloat16" in want and arr.dtype != want:
            import ml_dtypes
            arr = arr.astype(ml_dtypes.bfloat16)
        leaves.append(arr)
    assert like is not None, "pass `like=` target pytree"
    treedef = jax.tree_util.tree_structure(like)
    assert treedef.num_leaves == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, target {treedef.num_leaves}"
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


def restore_resharded(directory: str | Path, like: Params,
                      shardings: Optional[Params] = None,
                      step: Optional[int] = None) -> Tuple[Params, int]:
    """Elastic restore: place each global leaf onto a (possibly
    different) mesh/sharding -- node counts may change between runs."""
    host_tree, got_step = load_checkpoint(directory, step, like=like)
    if shardings is None:
        dev_tree = jax.tree_util.tree_map(jnp.asarray, host_tree)
    else:
        dev_tree = jax.tree_util.tree_map(
            lambda arr, sh: jax.device_put(jnp.asarray(arr), sh),
            host_tree, shardings)
    # restore original dtypes (np.save keeps them, but cast defensively)
    dev_tree = jax.tree_util.tree_map(
        lambda new, old: new.astype(old.dtype)
        if hasattr(old, "dtype") else new, dev_tree, like)
    return dev_tree, got_step


class CheckpointManager:
    """Async save + retention + resume."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save_async(self, step: int, tree: Params,
                   extra_meta: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot to host, then write on a background thread."""
        self.wait()                              # one in flight at a time
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                extra_meta)
                self._gc()
            except BaseException as ex:          # surfaced on next wait()
                self._error = ex

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Params,
             extra_meta: Optional[Dict[str, Any]] = None) -> Path:
        self.wait()
        path = save_checkpoint(self.directory, step, tree, extra_meta)
        self._gc()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        latest = self.directory / "LATEST"
        if not latest.exists():
            return None
        name = latest.read_text().strip()
        if not (self.directory / name / "manifest.json").exists():
            return None
        return int(name.split("_")[1])

    def restore(self, like: Params, shardings: Optional[Params] = None,
                step: Optional[int] = None) -> Tuple[Params, int]:
        return restore_resharded(self.directory, like, shardings, step)

    def steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.directory.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp")
                      and (p / "manifest.json").exists())

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:09d}",
                          ignore_errors=True)
