"""Deterministic, sharded, checkpointable data pipeline.

Design constraints at 1000-node scale:
  * every host must independently produce ITS shard of the global batch
    without coordination (pure function of (seed, step, host_id));
  * restart from a checkpoint must resume the exact token stream
    (the pipeline state is just the step counter);
  * elastic rescaling must keep the global stream identical (sharding
    is by global example index, not host-local counters).

The offline container has no corpus; examples are synthesized from a
counter-mode PRNG (threefry fold of (seed, global_example_idx)) --
statistically stationary, deterministic, and reproducible across any
host layout.  A real deployment swaps ``_example_tokens`` for a
tokenized-shard reader with the same indexing contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs
    n_patches: int = 0
    enc_frames: int = 0
    d_model: int = 0


class ShardedSyntheticDataset:
    """Counter-mode synthetic LM stream.

    ``batch_slice(step, lo, hi)`` returns examples [lo, hi) of the
    global batch at ``step`` -- hosts call it with their own range.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    def _example_tokens(self, idx: np.ndarray) -> np.ndarray:
        """Deterministic tokens for global example indices ``idx``
        ([n] int64) -> [n, seq_len+1] int32."""
        c = self.cfg
        n = idx.shape[0]
        # splitmix-style counter hash, vectorized over (example, position)
        pos = np.arange(c.seq_len + 1, dtype=np.uint64)[None, :]
        x = (idx.astype(np.uint64)[:, None] * np.uint64(0x9E3779B97F4A7C15)
             + pos * np.uint64(0xBF58476D1CE4E5B9)
             + np.uint64(c.seed) * np.uint64(0x94D049BB133111EB))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
        return (x % np.uint64(self.cfg.vocab)).astype(np.int32)

    def batch_slice(self, step: int, lo: int, hi: int
                    ) -> Dict[str, np.ndarray]:
        c = self.cfg
        base = np.int64(step) * c.global_batch
        idx = base + np.arange(lo, hi, dtype=np.int64)
        toks = self._example_tokens(idx)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.n_patches:
            rng = np.random.default_rng(c.seed * 1_000_003 + step)
            out["patches"] = rng.standard_normal(
                (hi - lo, c.n_patches, c.d_model)).astype(np.float32)
        if c.enc_frames:
            rng = np.random.default_rng(c.seed * 1_000_033 + step)
            out["frames"] = rng.standard_normal(
                (hi - lo, c.enc_frames, c.d_model)).astype(np.float32)
        return out

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch_slice(step, 0, self.cfg.global_batch)

    # ------------------------------------------------------------------ #
    def iterate(self, start_step: int = 0,
                host_id: int = 0, n_hosts: int = 1
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Host-local shard stream, resumable at any step."""
        c = self.cfg
        per = c.global_batch // n_hosts
        lo, hi = host_id * per, (host_id + 1) * per
        step = start_step
        while True:
            yield self.batch_slice(step, lo, hi)
            step += 1


def mix_datasets(streams: Sequence[Iterator[Dict[str, np.ndarray]]],
                 weights: Sequence[float], seed: int = 0
                 ) -> Iterator[Dict[str, np.ndarray]]:
    """Deterministic weighted mixture of example streams."""
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    rng = np.random.default_rng(seed)
    while True:
        k = int(rng.choice(len(streams), p=w))
        yield next(streams[k])
