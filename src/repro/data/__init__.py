from repro.data.pipeline import (DataConfig, ShardedSyntheticDataset,
                                 mix_datasets)

__all__ = ["DataConfig", "ShardedSyntheticDataset", "mix_datasets"]
