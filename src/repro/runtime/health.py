"""Heartbeat-based health tracking + straggler detection.

The control-plane logic that decides *when* to trigger an elastic
resize: hosts post heartbeats with their last completed step and step
latency; the monitor flags

  * DEAD hosts (no heartbeat within ``dead_after_s``),
  * STRAGGLERS (step latency > ``straggler_factor`` x fleet median,
    sustained for ``straggler_patience`` reports).

On a real cluster heartbeats arrive over RPC; in tests they are posted
directly.  The decision logic is identical either way.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional


class HostState(Enum):
    HEALTHY = "healthy"
    STRAGGLER = "straggler"
    DEAD = "dead"


@dataclass
class HostRecord:
    host_id: int
    last_seen: float = 0.0
    last_step: int = -1
    latencies: List[float] = field(default_factory=list)
    slow_reports: int = 0
    state: HostState = HostState.HEALTHY


@dataclass
class HealthDecision:
    dead: List[int]
    stragglers: List[int]
    should_resize: bool
    healthy_count: int


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, dead_after_s: float = 60.0,
                 straggler_factor: float = 2.0,
                 straggler_patience: int = 3,
                 latency_window: int = 20):
        self.hosts: Dict[int, HostRecord] = {
            i: HostRecord(i) for i in range(n_hosts)}
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.latency_window = latency_window

    # ------------------------------------------------------------------ #
    def heartbeat(self, host_id: int, step: int, step_latency_s: float,
                  now: Optional[float] = None) -> None:
        now = time.time() if now is None else now
        h = self.hosts[host_id]
        h.last_seen = now
        h.last_step = step
        h.latencies.append(step_latency_s)
        if len(h.latencies) > self.latency_window:
            h.latencies.pop(0)

    # ------------------------------------------------------------------ #
    def evaluate(self, now: Optional[float] = None) -> HealthDecision:
        now = time.time() if now is None else now
        recents = [h.latencies[-1] for h in self.hosts.values()
                   if h.latencies and h.state != HostState.DEAD]
        median = statistics.median(recents) if recents else 0.0

        dead, stragglers = [], []
        for h in self.hosts.values():
            if h.state == HostState.DEAD:
                dead.append(h.host_id)
                continue
            if h.last_seen and now - h.last_seen > self.dead_after_s:
                h.state = HostState.DEAD
                dead.append(h.host_id)
                continue
            if (median > 0 and h.latencies
                    and h.latencies[-1] > self.straggler_factor * median):
                h.slow_reports += 1
            else:
                h.slow_reports = 0
            if h.slow_reports >= self.straggler_patience:
                h.state = HostState.STRAGGLER
                stragglers.append(h.host_id)
            elif h.state == HostState.STRAGGLER:
                h.state = HostState.HEALTHY

        healthy = len(self.hosts) - len(dead)
        # resize when capacity is lost, or stragglers gate the fleet
        should = bool(dead) or len(stragglers) >= max(
            1, len(self.hosts) // 16)
        return HealthDecision(dead=dead, stragglers=stragglers,
                              should_resize=should,
                              healthy_count=healthy)

    def evict(self, host_id: int) -> None:
        self.hosts[host_id].state = HostState.DEAD

    def admit(self, host_id: int) -> None:
        self.hosts[host_id] = HostRecord(host_id, last_seen=time.time())
