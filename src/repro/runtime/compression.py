"""Gradient compression for the inter-pod (DCN) all-reduce.

Two composable schemes, both pure-JAX (jit-able, SPMD-shardable):

  * top-k sparsification with ERROR FEEDBACK: transmit the largest-|g|
    k fraction; the residual is carried into the next step's gradient
    (EF-SGD), which keeps convergence guarantees;
  * int8 quantization with per-tensor scale (symmetric), for a further
    4x over bf16 on the wire.

At 2 pods the pod-axis gradient all-reduce is the only DCN collective;
compressing it by ~50x (1% top-k + int8) moves the inter-pod term off
the roofline's critical path (napkin math in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------- #
# top-k with error feedback
# ---------------------------------------------------------------------- #
def topk_compress(g: jnp.ndarray, frac: float
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Keep the top-``frac`` fraction by |value|.

    Returns (values [k], indices [k], residual g - kept)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    residual = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx, residual.astype(g.dtype)


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    out = out.at[idx].set(vals)
    return out.reshape(shape).astype(dtype)


@dataclass
class ErrorFeedback:
    """Carries the compression residual across steps (EF-SGD)."""
    frac: float = 0.01

    def init(self, grads: Params) -> Params:
        return jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, g.dtype), grads)

    def compress(self, grads: Params, residuals: Params
                 ) -> Tuple[Params, Params]:
        """-> (compressed {vals, idx} tree, new residuals)."""
        def one(g, r):
            vals, idx, res = topk_compress(g + r.astype(g.dtype),
                                           self.frac)
            return {"vals": vals, "idx": idx}, res
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        res_leaves = treedef.flatten_up_to(residuals)
        outs = [one(g, r) for g, r in zip(leaves, res_leaves)]
        comp = treedef.unflatten([o[0] for o in outs])
        new_res = treedef.unflatten([o[1] for o in outs])
        return comp, new_res

    def decompress(self, comp: Params, like: Params) -> Params:
        def one(c, g):
            return topk_decompress(c["vals"], c["idx"], g.shape, g.dtype)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        comp_leaves = treedef.flatten_up_to(comp)
        return treedef.unflatten(
            [one(c, g) for c, g in zip(comp_leaves, leaves)])


# ---------------------------------------------------------------------- #
# int8 symmetric quantization
# ---------------------------------------------------------------------- #
def int8_quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)
