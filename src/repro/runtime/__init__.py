from repro.runtime.health import HeartbeatMonitor, HostState
from repro.runtime.elastic import plan_mesh, ElasticPlan
from repro.runtime.compression import (topk_compress, topk_decompress,
                                       int8_quantize, int8_dequantize,
                                       ErrorFeedback)

__all__ = ["HeartbeatMonitor", "HostState", "plan_mesh", "ElasticPlan",
           "topk_compress", "topk_decompress", "int8_quantize",
           "int8_dequantize", "ErrorFeedback"]
