"""Elastic mesh planning: pick the best (pods, dp, tp) grid for the
currently healthy chip count, preserving divisibility constraints.

Policy: keep tp fixed (model-parallel groups are latency-critical and
pinned to ICI neighborhoods); shrink/grow the data axis to the largest
divisor of the healthy chip count; whole lost pods drop the pod axis.
Rescale is implemented as: checkpoint -> new mesh -> resharded restore
(repro.checkpoint.restore_resharded), so the optimizer state survives
bit-exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ElasticPlan:
    pods: int
    dp: int
    tp: int
    used_chips: int
    idle_chips: int
    global_batch_scale: float      # new_dp*pods / old_dp*old_pods


def plan_mesh(healthy_chips: int, tp: int = 16,
              chips_per_pod: int = 256,
              old_plan: Optional[ElasticPlan] = None) -> ElasticPlan:
    """Largest (pods x dp x tp) grid fitting the healthy chip count."""
    assert healthy_chips >= tp, "cannot keep a tp group alive"
    pods = max(1, healthy_chips // chips_per_pod)
    per_pod = healthy_chips // pods
    dp = per_pod // tp
    # dp must be a power-of-two-ish divisor for batch divisibility; take
    # the largest power of two <= dp
    p2 = 1
    while p2 * 2 <= dp:
        p2 *= 2
    dp = p2
    used = pods * dp * tp
    scale = 1.0
    if old_plan is not None and old_plan.dp * old_plan.pods:
        scale = (dp * pods) / (old_plan.dp * old_plan.pods)
    return ElasticPlan(pods=pods, dp=dp, tp=tp, used_chips=used,
                       idle_chips=healthy_chips - used,
                       global_batch_scale=scale)
