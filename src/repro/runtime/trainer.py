"""Fault-tolerant training loop.

Wires together: sharded data -> jit train_step (TeAAL-mapped shardings)
-> async checkpointing -> heartbeat/straggler monitoring -> crash
recovery (restore from the last complete checkpoint) -> elastic resize
hooks (plan_mesh + restore_resharded).

On the offline container this runs the real loop on the 1-CPU mesh
with smoke configs; on a pod the identical code runs under
``jax.distributed`` (host-sharded data via ``Dataset.iterate``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataConfig, ShardedSyntheticDataset
from repro.launch import sharding as S
from repro.launch import steps as ST
from repro.models import api
from repro.optim import optimizers as opt
from repro.runtime.health import HeartbeatMonitor
from repro.sharding import logical

Params = Any


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    async_checkpoint: bool = True
    accum_steps: int = 1        # gradient-accumulation microbatches


@dataclass
class TrainState:
    params: Params
    opt_state: Params
    step: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 mesh: Optional[Mesh] = None,
                 optimizer: Optional[opt.Optimizer] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh or jax.make_mesh((1, 1), ("data", "model"))
        self.optimizer = optimizer or opt.for_config(cfg)
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep=tcfg.keep_checkpoints)
        self.monitor = HeartbeatMonitor(n_hosts=jax.process_count())
        self.data = ShardedSyntheticDataset(DataConfig(
            vocab=cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed,
            n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
            enc_frames=cfg.enc_frames if cfg.family == "encdec" else 0,
            d_model=cfg.d_model))
        self._step_fn = None
        self.metrics_log: list = []

    # ------------------------------------------------------------------ #
    def init_state(self, seed: int = 0) -> TrainState:
        logical.set_mesh(self.mesh)
        logical.set_rules(S.rules_for("train"))
        with self.mesh:
            params = api.init(self.cfg, jax.random.PRNGKey(seed))
            p_sh = S.param_shardings(params, self.mesh)
            params = jax.tree_util.tree_map(jax.device_put, params, p_sh)
            opt_state = self.optimizer.init(params)
        return TrainState(params=params, opt_state=opt_state, step=0)

    def _compiled_step(self):
        if self._step_fn is None:
            fn = ST.make_train_step(self.cfg, self.optimizer,
                                    accum_steps=self.tcfg.accum_steps)
            self._step_fn = jax.jit(fn, donate_argnums=(0, 1))
        return self._step_fn

    def _device_batch(self, batch: Dict[str, np.ndarray]):
        fixed = {}
        for k, v in batch.items():
            if k in ("patches", "frames"):
                v = v.astype(np.float32)
            fixed[k] = jnp.asarray(v)
        return fixed

    # ------------------------------------------------------------------ #
    def restore_or_init(self) -> TrainState:
        state = self.init_state()
        latest = self.ckpt.latest_step()
        if latest is None:
            return state
        tree = {"params": state.params, "opt_state": state.opt_state}
        shardings = {
            "params": S.param_shardings(state.params, self.mesh),
            "opt_state": jax.tree_util.tree_map(
                lambda s: NamedSharding(self.mesh, s),
                S.param_pspecs(state.opt_state, self.mesh)),
        }
        restored, step = self.ckpt.restore(tree, shardings)
        return TrainState(params=restored["params"],
                          opt_state=restored["opt_state"], step=step)

    def train(self, state: Optional[TrainState] = None,
              on_step: Optional[Callable[[int, Dict], None]] = None
              ) -> TrainState:
        state = state or self.restore_or_init()
        step_fn = self._compiled_step()
        logical.set_mesh(self.mesh)
        logical.set_rules(S.rules_for("train"))
        host = jax.process_index()
        try:
            with self.mesh:
                while state.step < self.tcfg.total_steps:
                    t0 = time.time()
                    batch = self._device_batch(
                        self.data.global_batch_at(state.step))
                    params, opt_state, metrics = step_fn(
                        state.params, state.opt_state, batch)
                    loss = float(metrics["loss"])
                    if not np.isfinite(loss):
                        raise FloatingPointError(
                            f"non-finite loss at step {state.step}")
                    state = TrainState(params, opt_state, state.step + 1)
                    dt = time.time() - t0
                    self.monitor.heartbeat(host, state.step, dt)
                    if state.step % self.tcfg.log_every == 0:
                        rec = {"step": state.step, "loss": loss,
                               "grad_norm": float(metrics["grad_norm"]),
                               "s_per_step": dt}
                        self.metrics_log.append(rec)
                        if on_step:
                            on_step(state.step, rec)
                    if state.step % self.tcfg.checkpoint_every == 0:
                        self._save(state)
        finally:
            self.ckpt.wait()
            logical.set_mesh(None)
            logical.set_rules(None)
        self._save(state)
        self.ckpt.wait()
        return state

    def _save(self, state: TrainState) -> None:
        tree = {"params": state.params, "opt_state": state.opt_state}
        if self.tcfg.async_checkpoint:
            self.ckpt.save_async(state.step, tree)
        else:
            self.ckpt.save(state.step, tree)

    # ------------------------------------------------------------------ #
    def run_with_recovery(self, max_restarts: int = 2) -> TrainState:
        """Crash-tolerant outer loop: on any step failure, reload the
        newest complete checkpoint and continue."""
        attempts = 0
        while True:
            try:
                return self.train()
            except (FloatingPointError, RuntimeError) as ex:
                attempts += 1
                if attempts > max_restarts:
                    raise
                print(f"[trainer] step failure ({ex}); restoring from "
                      f"checkpoint (attempt {attempts})")
                self._step_fn = None
