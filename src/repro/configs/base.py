"""Model / shape configuration dataclasses shared across the framework."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0                 # shared (always-on) experts
    d_expert: Optional[int] = None    # expert FFN width (default: d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    # n_heads derived: d_inner / head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default: d_model // n_heads
    # architectural options
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen2
    nonparam_ln: bool = False         # olmo: non-parametric LayerNorm
    tie_embeddings: bool = False
    act: str = "swiglu"               # swiglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): layers per block and which position is attention
    hybrid_block: int = 8             # 1 attention : 7 mamba
    hybrid_attn_idx: int = 4
    moe_every: int = 1                # jamba: MoE on every 2nd layer
    # enc-dec (whisper): encoder layers (decoder = n_layers)
    enc_layers: int = 0
    enc_frames: int = 1500            # precomputed frame embeddings (stub)
    # vlm (llava): patch embeddings prepended (stub)
    n_patches: int = 0
    # Megatron-style sequence parallelism: residual stream sharded over
    # 'model' between blocks (AG before attention/FFN, RS after)
    seq_parallel: bool = False
    # query-block size for chunked reference attention (None = one block)
    attn_chunk: Optional[int] = 1024
    # scan over layers for compile scalability
    scan_layers: bool = True
    # rematerialize each layer's activations in backward (train memory)
    remat: bool = True
    # use Pallas kernels on TPU (reference jnp paths otherwise)
    use_kernels: bool = False
    dtype: str = "bfloat16"

    @property
    def hdim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM / hybrid only)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True                   # all assigned archs generate tokens

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid"
                         else self.hybrid_block),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            enc_layers=2 if self.enc_layers else 0,
            enc_frames=16 if self.enc_layers else self.enc_frames,
            n_patches=8 if self.n_patches else 0,
            scan_layers=False,
        )
        if self.moe:
            kw["moe"] = replace(self.moe, n_experts=min(self.moe.n_experts, 4),
                                top_k=min(self.moe.top_k, 2),
                                n_shared=min(self.moe.n_shared, 1),
                                d_expert=64)
        if self.ssm:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=16, chunk=16)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cells(cfg: ModelConfig) -> List[str]:
    """The shape cells this architecture runs (long_500k only for
    sub-quadratic families, per the brief)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        out.append("long_500k")
    return out


def param_count(cfg: ModelConfig) -> float:
    """Approximate parameter count (for MODEL_FLOPS = 6*N*D)."""
    d, h = cfg.d_model, cfg.hdim
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    att = d * (cfg.n_heads * h) + 2 * d * (cfg.n_kv_heads * h) \
        + (cfg.n_heads * h) * d
    ffn_mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    per_layer: float = 0.0
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        per_layer = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim) \
            + d_in * d + d_in * s.d_conv
        return cfg.n_layers * per_layer + emb
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        mamba = d * (2 * d_in + 2 * s.d_state + d_in // s.head_dim) \
            + d_in * d
        n_attn = cfg.n_layers // cfg.hybrid_block
        n_mamba = cfg.n_layers - n_attn
        moe_layers = cfg.n_layers // cfg.moe_every
        dense_layers = cfg.n_layers - moe_layers
        ffn = ffn_mult * d * cfg.d_ff
        moe_ffn = cfg.moe.n_experts * ffn_mult * d * \
            (cfg.moe.d_expert or cfg.d_ff)
        return (n_attn * att + n_mamba * mamba + dense_layers * ffn
                + moe_layers * moe_ffn + emb)
    if cfg.family == "moe":
        ffn = cfg.moe.n_experts * ffn_mult * d * (cfg.moe.d_expert or cfg.d_ff)
        ffn += cfg.moe.n_shared * ffn_mult * d * (cfg.moe.d_expert
                                                  or cfg.d_ff)
        ffn += d * cfg.moe.n_experts            # router
    else:
        ffn = ffn_mult * d * cfg.d_ff
    layers = cfg.n_layers + cfg.enc_layers
    return layers * (att + ffn) + emb


def active_param_count(cfg: ModelConfig) -> float:
    """Active params per token (MoE: only routed top-k experts count)."""
    if cfg.family not in ("moe", "hybrid") or cfg.moe is None:
        return param_count(cfg)
    d = cfg.d_model
    ffn_mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    de = cfg.moe.d_expert or cfg.d_ff
    full = cfg.moe.n_experts * ffn_mult * d * de
    active = (cfg.moe.top_k + cfg.moe.n_shared) * ffn_mult * d * de
    if cfg.family == "hybrid":
        moe_layers = cfg.n_layers // cfg.moe_every
        return param_count(cfg) - moe_layers * (full - active
                                                - cfg.moe.n_shared
                                                * ffn_mult * d * de)
    return param_count(cfg) - cfg.n_layers * (full + cfg.moe.n_shared
                                              * ffn_mult * d * de
                                              - active)
