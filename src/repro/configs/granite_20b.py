"""granite-20b [dense, code]: 52L d_model=6144 48H (GQA kv=1 = MQA)
d_ff=24576 vocab=49152  [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,              # MQA
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    act="gelu",                # granite code models use gelu MLPs
)

SMOKE = CONFIG.smoke()
