"""whisper-small [audio]: 12L d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865 - enc-dec, conv frontend STUB (precomputed frame
embeddings via ``input_specs``)  [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,               # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    act="gelu",
    enc_layers=12,
    enc_frames=1500,           # 30 s of audio after the conv stub
)

SMOKE = CONFIG.smoke()
