"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 - anyres tiling; patch embeddings are a precomputed STUB
prepended to the token stream  [hf:llava-hf/...; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    rope_theta=5e6,
    # anyres: base 576 + 4 tiles x 576 patches = 2880 patch embeddings
    n_patches=2880,
)

SMOKE = CONFIG.smoke()
