"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2, Mamba+attn 1:7
interleave  [arXiv:2403.19887; hf]."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, n_shared=0, d_expert=24576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    hybrid_block=8,            # 1 attention : 7 mamba
    hybrid_attn_idx=4,
    moe_every=2,               # MoE on every other layer
)

SMOKE = CONFIG.smoke()
