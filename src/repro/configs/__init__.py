"""Architecture configs: one module per assigned architecture.

``get(arch_id)`` returns the full published config; ``get_smoke(arch_id)``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ModelConfig, ShapeSpec, SHAPES

ARCH_IDS: List[str] = [
    "granite-20b",
    "qwen3-14b",
    "qwen2-7b",
    "olmo-1b",
    "grok-1-314b",
    "qwen2-moe-a2.7b",
    "whisper-small",
    "jamba-1.5-large-398b",
    "mamba2-1.3b",
    "llava-next-34b",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.SMOKE


__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "ARCH_IDS", "get",
           "get_smoke"]
