"""Pure-jnp oracles for every Pallas kernel (the allclose authority)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q: [b, h, sq, d]; k, v: [b, hkv, sk, d] (GQA broadcast)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    qr = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qr, kf) / math.sqrt(d)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)


def block_sparse_matmul_ref(a_masked: jnp.ndarray,
                            b: jnp.ndarray) -> jnp.ndarray:
    """Oracle over the tile-masked dense A (float32 accumulate)."""
    return (a_masked.astype(jnp.float32) @ b.astype(jnp.float32))


def tile_mask(a: np.ndarray, bm: int, bk: int) -> np.ndarray:
    """Zero out (bm x bk) tiles of ``a`` that are entirely zero (no-op
    numerically -- returns ``a`` with the same nonzero tiles)."""
    m, k = a.shape
    out = np.zeros_like(a)
    for i in range(0, m, bm):
        for j in range(0, k, bk):
            t = a[i:i + bm, j:j + bk]
            if np.any(t != 0):
                out[i:i + bm, j:j + bk] = t
    return out


def ssd_chunk_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                  c: jnp.ndarray) -> jnp.ndarray:
    """x: [B, nc, l, H, P]; a: [B, H, nc, l]; b, c: [B, nc, l, N]."""
    from repro.models.ssm import _segsum
    Lmask = jnp.exp(_segsum(a.astype(jnp.float32)))    # [B,H,nc,l,l]
    g = jnp.einsum("bcln,bcsn->bcls", c.astype(jnp.float32),
                   b.astype(jnp.float32))
    return jnp.einsum("bcls,bhcls,bcshp->bclhp", g, Lmask,
                      x.astype(jnp.float32))


def intersect_sorted_ref(a, b) -> jnp.ndarray:
    """Oracle for the sorted-coordinate intersection kernel."""
    import numpy as np
    PAD = np.iinfo(np.int32).max
    a = np.asarray(a)
    b = np.asarray(b)
    pos = np.searchsorted(b, a)
    pos_c = np.clip(pos, 0, len(b) - 1)
    hit = (b[pos_c] == a) & (a != PAD)
    return jnp.asarray(np.where(hit, pos_c, -1).astype(np.int32))
