"""Pluggable kernel-backend registry for the vector engine's seams.

The columnar ``VectorBackend`` funnels every data-parallel primitive
through four *seams* -- ``intersect_keys`` / ``union_k_keys`` /
``lookup_keys`` / ``segmented_reduce`` (plus the 2-ary ``union_keys``
special case).  This module hosts the lowerings of those seams for each
kernel backend and the registry that selects between them:

  * ``numpy``            reference lowerings (vectorized ``searchsorted``
                         / ``bincount``) -- the parity oracle every other
                         backend must match bit-exactly.
  * ``jax-jit``          the same formulations as jitted XLA programs
                         (pow2-padded shapes to bound retraces, x64
                         enabled so packed int64 keys survive).
  * ``pallas-interpret`` the Pallas kernels (`intersect_sorted`,
                         ``merge_sorted``, ``multi_merge_ranks``) run in
                         interpret mode -- the CI leg that keeps the
                         kernel bodies from bit-rotting on CPU runners.
  * ``pallas-tpu``       the same kernels compiled to Mosaic; requires a
                         TPU backend and refuses to resolve without one.

Selection order: an explicit ``VectorBackend(kernel_backend=...)``
argument wins, else the ``REPRO_KERNEL_BACKEND`` environment variable,
else ``auto`` (pallas-tpu on TPU hosts, numpy otherwise).

Parity contract (DESIGN.md "kernel dispatch"): for any admissible
input, every backend returns arrays *bit-identical* to the numpy
lowering -- positions, union orders, and float accumulation order all
included.  Inputs outside a backend's admissible domain (e.g. keys
beyond int32 for the Pallas kernels, semirings without a vectorized
reduction for the jax scatter path) delegate to the numpy lowering per
call, so parity is preserved rather than approximated.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

_I32_MAX = np.iinfo(np.int32).max
_I64_PAD = np.iinfo(np.int64).max


# ---------------------------------------------------------------------- #
# reference lowerings
# ---------------------------------------------------------------------- #
class NumpyKernels:
    """Vectorized ``searchsorted`` / ``bincount`` seam lowerings: the
    bit-exactness oracle for every other backend."""

    name = "numpy"

    # -------------------------------------------------------------- #
    def intersect_keys(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Positions in ``b`` of every element of ``a`` (both sorted
        int64 key arrays; keys unique per array), -1 where absent."""
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        if len(a) == 0 or len(b) == 0:
            return np.full(len(a), -1, dtype=np.int64)
        pos = np.searchsorted(b, a)
        safe = np.minimum(pos, len(b) - 1)
        hit = (pos < len(b)) & (b[safe] == a)
        return np.where(hit, safe, -1)

    # -------------------------------------------------------------- #
    def _positions(self, a: np.ndarray, u: np.ndarray) -> np.ndarray:
        pos = np.searchsorted(a, u)
        safe = np.minimum(pos, len(a) - 1)
        hit = (pos < len(a)) & (a[safe] == u)
        return np.where(hit, safe, -1).astype(np.int64)

    def _merged_union(self, arrays: List[np.ndarray]) -> np.ndarray:
        """Sorted union of the non-empty arrays (hook point: subclasses
        override just the merge and inherit the position gathers)."""
        if len(arrays) == 2:
            return np.union1d(arrays[0], arrays[1])
        return np.unique(np.concatenate(arrays))

    def union_keys(self, a: np.ndarray, b: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Sorted union of two sorted int64 key arrays (keys unique per
        array).  Returns (union, pos_a, pos_b): for every union element
        its position in ``a`` / ``b`` or -1."""
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        if len(a) == 0:
            return (b.copy(), np.full(len(b), -1, dtype=np.int64),
                    np.arange(len(b), dtype=np.int64))
        if len(b) == 0:
            return (a.copy(), np.arange(len(a), dtype=np.int64),
                    np.full(len(a), -1, dtype=np.int64))
        u = self._merged_union([a, b])
        return u, self._positions(a, u), self._positions(b, u)

    def union_k_keys(self, arrays) -> Tuple[np.ndarray, list]:
        """Sorted union of k sorted int64 key arrays (keys unique per
        array).  Returns (union, [pos_i]): for every union element its
        position in array i, or -1 where absent."""
        arrays = [np.asarray(a, dtype=np.int64) for a in arrays]
        if len(arrays) == 1:
            a = arrays[0]
            return a.copy(), [np.arange(len(a), dtype=np.int64)]
        if len(arrays) == 2:
            u, pa, pb = self.union_keys(arrays[0], arrays[1])
            return u, [pa, pb]
        nonempty = [a for a in arrays if len(a)]
        if not nonempty:
            z = np.zeros(0, dtype=np.int64)
            return z, [z.copy() for _ in arrays]
        u = self._merged_union(nonempty)
        out = []
        for a in arrays:
            if len(a) == 0:
                out.append(np.full(len(u), -1, dtype=np.int64))
            else:
                out.append(self._positions(a, u))
        return u, out

    # -------------------------------------------------------------- #
    def lookup_keys(self, hay: np.ndarray, probes: np.ndarray
                    ) -> np.ndarray:
        """Positions in ``hay`` (sorted int64, unique) of every
        ``probes`` element (arbitrary order, duplicates fine), -1 where
        absent."""
        hay = np.asarray(hay, dtype=np.int64)
        probes = np.asarray(probes, dtype=np.int64)
        if len(probes) == 0 or len(hay) == 0:
            return np.full(len(probes), -1, dtype=np.int64)
        pos = np.searchsorted(hay, probes)
        safe = np.minimum(pos, len(hay) - 1)
        hit = (pos < len(hay)) & (hay[safe] == probes)
        return np.where(hit, safe, -1)

    # -------------------------------------------------------------- #
    def segmented_reduce(self, vals: np.ndarray, starts: np.ndarray,
                         semiring=None,
                         group_ids: Optional[np.ndarray] = None
                         ) -> np.ndarray:
        """Semiring-parameterized segmented reduction over a
        fused-key-sorted value stream: ``starts[g]`` is the first index
        of group ``g`` (ascending, ``starts[0] == 0``); returns one
        reduced value per group.

        Values fold strictly left-to-right within each group,
        bit-identical to the interpreter's sequential ``semiring.add``
        chain.  Three lowerings, fastest admissible wins:

        * float addition (``add_vec is np.add``, the arithmetic
          semiring) -- one ``np.bincount`` pass: its weighted
          accumulation is a plain C loop in input order, and seeding
          from 0.0 is exact for the nonzero payloads the nz-filtered
          stream carries.  (NOT ``np.add.reduceat``: reduceat
          pairwise-sums like ``reduce``, verified non-bit-identical to
          the sequential fold.)
        * a declared ``add_ufunc`` (min-plus: min is exact under any
          association) -- one ``ufunc.reduceat``.
        * otherwise -- a step-loop over ``add_vec`` bounded by the
          largest group.

        ``group_ids`` (optional, 0-based group index per element) lets
        a caller that already materialized the group boundaries skip
        their reconstruction on the bincount path."""
        vals = np.asarray(vals)
        starts = np.asarray(starts, dtype=np.int64)
        n = len(vals)
        if len(starts) == 0:
            return vals[:0].copy()
        if (semiring is None or semiring.add_vec is np.add) and \
                vals.dtype == np.float64:
            gids = group_ids
            if gids is None:
                gids = np.zeros(n, dtype=np.int64)
                gids[starts[1:]] = 1
                np.cumsum(gids, out=gids)
            return np.bincount(gids, weights=vals, minlength=len(starts))
        ufunc = None if semiring is None else semiring.add_ufunc
        if ufunc is not None:
            return ufunc.reduceat(vals, starts)
        add_vec = np.add if semiring is None else semiring.add_vec
        counts = np.diff(np.append(starts, n))
        sums = vals[starts].copy()
        step = 1
        max_c = int(counts.max())
        while step < max_c:
            act = np.flatnonzero(counts > step)
            sums[act] = add_vec(sums[act], vals[starts[act] + step])
            step += 1
        return sums


# ---------------------------------------------------------------------- #
# jax-jit: the same formulations as XLA programs
# ---------------------------------------------------------------------- #
def _pad_pow2(a: np.ndarray, fill) -> np.ndarray:
    """Pad to the next power-of-two length (min 1) so jit retraces stay
    O(log n) across the chunked frontier's varying stream sizes."""
    n = len(a)
    m = 1 << max(n, 1).bit_length() if n & (n - 1) or n == 0 else n
    if m == n:
        return a
    out = np.full(m, fill, a.dtype)
    out[:n] = a
    return out


@functools.cache
def _jx():
    """Jitted seam programs, built once.  All run under
    ``enable_x64`` (packed offset keys reach 2^62)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def positions(hay, probes):
        # positions of probes in hay, -1 where absent; pads
        # (INT64_MAX) in hay sort past every real key, pad probes
        # resolve to hay pads and are sliced off by the caller
        n = hay.shape[0]
        pos = jnp.searchsorted(hay, probes)
        safe = jnp.minimum(pos, n - 1)
        hit = (pos < n) & (hay[safe] == probes)
        return jnp.where(hit, safe, -1)

    @jax.jit
    def merge_sort(cat):
        return jnp.sort(cat)

    @functools.partial(jax.jit, static_argnums=(2,))
    def seg_sum(vals, gids, out_len):
        return jnp.zeros(out_len, vals.dtype).at[gids].add(vals)

    @functools.partial(jax.jit, static_argnums=(2,))
    def seg_min(vals, gids, out_len):
        init = jnp.full(out_len, jnp.inf, vals.dtype)
        return init.at[gids].min(vals)

    @functools.partial(jax.jit, static_argnums=(2,))
    def seg_max(vals, gids, out_len):
        init = jnp.full(out_len, -jnp.inf, vals.dtype)
        return init.at[gids].max(vals)

    return positions, merge_sort, seg_sum, seg_min, seg_max


class JaxJitKernels(NumpyKernels):
    """XLA lowerings of the seams via ``jax.jit``: one fused program
    per seam, shapes padded to powers of two to bound retraces.

    Positions/unions are the identical binary-search formulation
    (bit-exact by construction); the float segmented reduction uses an
    XLA scatter-add, which applies duplicate updates in stream order on
    CPU/TPU -- the same sequential fold as the bincount oracle (parity
    is CI-asserted, not assumed)."""

    name = "jax-jit"

    def _jpositions(self, hay: np.ndarray, probes: np.ndarray
                    ) -> np.ndarray:
        positions, _, _, _, _ = _jx()
        from jax.experimental import enable_x64
        with enable_x64():
            out = positions(_pad_pow2(hay, _I64_PAD),
                            _pad_pow2(probes, _I64_PAD))
        # hits against hay's pad tail are pad probes only (real keys
        # are < 2^63-1), already sliced off; misses are already -1
        return np.asarray(out)[:len(probes)].astype(np.int64)

    def intersect_keys(self, a, b):
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        if len(a) == 0 or len(b) == 0:
            return np.full(len(a), -1, dtype=np.int64)
        return self._jpositions(b, a)

    def _positions(self, a, u):
        return self._jpositions(a, u)

    def _merged_union(self, arrays):
        _, merge_sort, _, _, _ = _jx()
        from jax.experimental import enable_x64
        total = sum(len(a) for a in arrays)
        cat = _pad_pow2(np.concatenate(arrays), _I64_PAD)
        with enable_x64():
            merged = np.asarray(merge_sort(cat))[:total]
        keep = np.ones(total, dtype=bool)
        keep[1:] = merged[1:] != merged[:-1]
        return merged[keep]

    def lookup_keys(self, hay, probes):
        hay = np.asarray(hay, dtype=np.int64)
        probes = np.asarray(probes, dtype=np.int64)
        if len(probes) == 0 or len(hay) == 0:
            return np.full(len(probes), -1, dtype=np.int64)
        if int(probes.max()) >= _I64_PAD:
            return super().lookup_keys(hay, probes)
        return self._jpositions(hay, probes)

    def segmented_reduce(self, vals, starts, semiring=None,
                         group_ids=None):
        vals = np.asarray(vals)
        starts = np.asarray(starts, dtype=np.int64)
        n = len(vals)
        if len(starts) == 0 or n == 0:
            return super().segmented_reduce(vals, starts, semiring,
                                            group_ids)
        ufunc = None if semiring is None else semiring.add_ufunc
        is_sum = (semiring is None or semiring.add_vec is np.add) and \
            vals.dtype == np.float64
        if not is_sum and ufunc not in (np.minimum, np.maximum):
            return super().segmented_reduce(vals, starts, semiring,
                                            group_ids)
        gids = group_ids
        if gids is None:
            gids = np.zeros(n, dtype=np.int64)
            gids[starts[1:]] = 1
            np.cumsum(gids, out=gids)
        n_groups = len(starts)
        # pad the scatter stream with writes to a dummy slot past the
        # real groups, so the output length is a pow2 static shape
        out_len = 1 << max(n_groups + 1, 2).bit_length()
        _, _, seg_sum, seg_min, seg_max = _jx()
        from jax.experimental import enable_x64
        fill = 0.0 if is_sum else (np.inf if ufunc is np.minimum
                                   else -np.inf)
        pv = _pad_pow2(np.ascontiguousarray(vals, dtype=np.float64), fill)
        pg = np.full(len(pv), out_len - 1, dtype=np.int64)
        pg[:n] = gids
        fn = seg_sum if is_sum else (seg_min if ufunc is np.minimum
                                     else seg_max)
        with enable_x64():
            out = fn(pv, pg, int(out_len))
        res = np.asarray(out)[:n_groups]
        if vals.dtype != np.float64:
            res = res.astype(vals.dtype)
        return res


# ---------------------------------------------------------------------- #
# pallas: the device kernels (interpret mode on CPU, Mosaic on TPU)
# ---------------------------------------------------------------------- #
def _fits_i32(a: np.ndarray) -> bool:
    return len(a) == 0 or int(a[-1]) < _I32_MAX


class PallasKernels(NumpyKernels):
    """The Pallas kernels behind the seams: skip-ahead intersection,
    merge-path 2-way union, k-ary ``multi_merge_ranks``.  Kernel input
    contracts are int32 keys padded with INT32_MAX to a block multiple;
    inputs whose key domain exceeds int32 delegate to the numpy
    lowering per call (parity over partial coverage).  The segmented
    reduction inherits the numpy lowering -- a segmented-scan kernel is
    the next seam to move on-device."""

    def __init__(self, interpret: bool):
        self.interpret = interpret
        self.name = "pallas-interpret" if interpret else "pallas-tpu"

    def intersect_keys(self, a, b):
        a = np.asarray(a, dtype=np.int64)
        b = np.asarray(b, dtype=np.int64)
        if len(a) == 0 or len(b) == 0:
            return np.full(len(a), -1, dtype=np.int64)
        if not (_fits_i32(a) and _fits_i32(b)):
            return super().intersect_keys(a, b)
        import jax.numpy as jnp
        from repro.kernels import intersect as _isect
        from repro.kernels import ops as _ops
        pa = _ops.pad_sorted(a.astype(np.int32), 512)
        pb = _ops.pad_sorted(b.astype(np.int32), 512)
        idx = np.asarray(_isect.intersect_sorted(
            jnp.asarray(pa), jnp.asarray(pb), block=512,
            interpret=self.interpret))[:len(a)]
        return idx.astype(np.int64)

    def _merged_union(self, arrays):
        if not all(_fits_i32(a) for a in arrays):
            return super()._merged_union(arrays)
        import jax.numpy as jnp
        from repro.kernels import ops as _ops
        if len(arrays) == 2:
            # merge-path kernel + host dedup; pads merge to the tail
            pa32 = _ops.pad_sorted(arrays[0].astype(np.int32), 256)
            pb32 = _ops.pad_sorted(arrays[1].astype(np.int32), 256)
            merged, _ = _ops.merge_sorted(
                jnp.asarray(pa32), jnp.asarray(pb32), block=256,
                interpret=self.interpret)
            merged = np.asarray(merged, dtype=np.int64)
            merged = merged[merged < _I32_MAX]
        else:
            # k-ary multi-merge: every element finds its global rank in
            # the stable merge in one launch
            n_pad = max(len(_ops.pad_sorted(a.astype(np.int32), 256))
                        for a in arrays)
            stacked = np.stack([
                np.concatenate([a.astype(np.int32),
                                np.full(n_pad - len(a), _I32_MAX,
                                        np.int32)])
                for a in arrays])
            ranks = np.asarray(_ops.multi_merge_ranks(
                jnp.asarray(stacked), interpret=self.interpret))
            total = sum(len(a) for a in arrays)
            # real keys are < INT32_MAX, so every pad ranks after every
            # real element and real ranks land in [0, total)
            merged = np.empty(total, dtype=np.int64)
            for i, a in enumerate(arrays):
                merged[ranks[i, :len(a)]] = a
        keep = np.ones(len(merged), dtype=bool)
        keep[1:] = merged[1:] != merged[:-1]
        return merged[keep]

    def lookup_keys(self, hay, probes):
        hay = np.asarray(hay, dtype=np.int64)
        probes = np.asarray(probes, dtype=np.int64)
        if len(probes) == 0 or len(hay) == 0:
            return np.full(len(probes), -1, dtype=np.int64)
        if not (_fits_i32(hay) and int(probes.max()) < _I32_MAX
                and int(probes.min()) >= 0):
            return super().lookup_keys(hay, probes)
        # probes are sorted, pushed through the skip-ahead intersection
        # kernel, and unsorted
        order = np.argsort(probes, kind="stable")
        idx_sorted = self.intersect_keys(probes[order], hay)
        idx = np.empty(len(probes), dtype=np.int64)
        idx[order] = idx_sorted
        return idx


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
_INSTANCES: dict = {}

KERNEL_BACKENDS = ("numpy", "jax-jit", "pallas-interpret", "pallas-tpu")

#: environment override consulted when no explicit backend is passed
ENV_VAR = "REPRO_KERNEL_BACKEND"


def _make(name: str):
    if name == "numpy":
        return NumpyKernels()
    if name == "jax-jit":
        return JaxJitKernels()
    if name == "pallas-interpret":
        return PallasKernels(interpret=True)
    if name == "pallas-tpu":
        import jax
        if jax.default_backend() != "tpu":
            raise RuntimeError(
                "kernel backend 'pallas-tpu' requires a TPU jax backend "
                f"(found {jax.default_backend()!r}); use "
                "'pallas-interpret' for CPU validation")
        return PallasKernels(interpret=False)
    raise ValueError(
        f"unknown kernel backend {name!r}; choose from {KERNEL_BACKENDS} "
        f"or 'auto'")


#: why the last ``auto`` probe fell back to numpy (None when it found a
#: TPU or has not run); surfaced instead of silently swallowed
AUTO_PROBE_ERROR: Optional[str] = None


def _probe_tpu() -> bool:
    """Is a TPU jax backend available?  Failures are narrowed to the
    ways a probe can actually fail -- jax missing (ImportError), plugin
    / runtime initialization broken (RuntimeError), device files
    unreadable (OSError) -- and the reason is recorded on
    ``AUTO_PROBE_ERROR`` rather than discarded."""
    global AUTO_PROBE_ERROR
    try:
        import jax
        on_tpu = jax.default_backend() == "tpu"
    except (ImportError, RuntimeError, OSError) as exc:
        AUTO_PROBE_ERROR = f"{type(exc).__name__}: {exc}"
        return False
    AUTO_PROBE_ERROR = None
    return on_tpu


def resolve_kernel_backend(which=None):
    """Resolve a kernel backend: an instance passes through, a name hits
    the registry, ``None`` consults ``$REPRO_KERNEL_BACKEND`` then
    ``auto`` (pallas-tpu on TPU hosts, numpy elsewhere)."""
    if which is not None and not isinstance(which, str):
        return which
    name = which or os.environ.get(ENV_VAR) or "auto"
    if name == "auto":
        name = "pallas-tpu" if _probe_tpu() else "numpy"
    inst = _INSTANCES.get(name)
    if inst is None:
        inst = _INSTANCES[name] = _make(name)
    return inst


# ---------------------------------------------------------------------- #
# guarded dispatch: the per-seam degradation chain
# ---------------------------------------------------------------------- #
#: degradation order -- each seam call starts at its primary backend's
#: position in this chain and walks right until one lowering succeeds
DEGRADATION_CHAIN = ("pallas-tpu", "pallas-interpret", "jax-jit", "numpy")

#: the five seam methods the guard mediates
GUARDED_SEAMS = ("intersect_keys", "union_keys", "union_k_keys",
                 "lookup_keys", "segmented_reduce")

#: substrings of backend error text classified transient (worth a
#: bounded retry on the *same* backend before downgrading)
TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "DEADLINE_EXCEEDED",
                     "UNAVAILABLE", "ABORTED")


@dataclass(frozen=True)
class DowngradeEvent:
    """One structured record of the guard acting on a seam fault.

    ``action`` is one of:

    * ``retry``       a transient fault; the same seam x backend pair is
                      retried after backoff,
    * ``downgrade``   the seam call moved to ``fallback`` (the next
                      backend in the chain),
    * ``demote``      the seam x backend pair crossed the failure
                      threshold and is skipped for the rest of the
                      process,
    * ``unavailable`` the backend could not even be constructed (e.g.
                      pallas-tpu on a CPU host).

    Every caught seam fault produces at least one event -- the guard
    never swallows silently.

    ``ts_us`` is a monotonic microsecond timestamp and ``einsum`` the
    Einsum active on the owning executor, both stamped at record time
    (``GuardedKernels._record``) so exported traces order events
    deterministically even though the executor drains them per-Einsum
    batch."""
    seam: str
    backend: str
    fallback: str            # next backend tried ("" for retry/demote)
    action: str              # retry | downgrade | demote | unavailable
    reason: str
    exc_type: str
    attempts: int = 1
    ts_us: float = 0.0       # monotonic; stamped by _record
    einsum: str = ""         # active Einsum at record time

    def as_dict(self) -> Dict[str, object]:
        return {"seam": self.seam, "backend": self.backend,
                "fallback": self.fallback, "action": self.action,
                "reason": self.reason, "exc_type": self.exc_type,
                "attempts": self.attempts, "ts_us": self.ts_us,
                "einsum": self.einsum}


class KernelChainExhausted(RuntimeError):
    """Every backend in the degradation chain failed for a seam call.
    ``VectorBackend`` treats this like any other execution fault: the
    affected Einsum falls back to the interpreter oracle."""


class SeamPostconditionError(RuntimeError):
    """A seam lowering returned an output violating the seam's
    contract (wrong length, out-of-range positions, unsorted union,
    non-finite reduction under an arithmetic semiring)."""


# process-wide guard state: demotions are permanent for the process (a
# backend that failed N times is not coming back), and the event
# counter is what chaos runs compare against injected-fault counts
_GUARD_LOCK = threading.Lock()
_DEMOTED: Set[Tuple[str, str]] = set()
_FAIL_COUNTS: Dict[Tuple[str, str], int] = {}
_EVENTS_RECORDED = 0


def events_recorded() -> int:
    """Total DowngradeEvents recorded process-wide (chaos accounting:
    must cover every injected seam fault, else the run was silent)."""
    return _EVENTS_RECORDED


def reset_guard_state() -> None:
    """Test hook: forget demotions, failure tallies and the event
    counter."""
    global _EVENTS_RECORDED
    with _GUARD_LOCK:
        _DEMOTED.clear()
        _FAIL_COUNTS.clear()
        _EVENTS_RECORDED = 0


def _is_transient(exc: BaseException) -> bool:
    if type(exc).__name__ == "InjectedTransientFault":
        return True
    msg = str(exc)
    return any(tok in msg for tok in TRANSIENT_MARKERS)


# lazily-resolved cross-module hooks, cached after the first call:
# these run on every guarded seam call, so repeated import-machinery
# lookups would tax the hot path
_INJECTOR_FN = None
_GUARDS_ENABLED_FN = None


def _active_injector():
    global _INJECTOR_FN
    if _INJECTOR_FN is None:
        try:
            from repro.testing.faults import active_injector
        except ImportError:              # pragma: no cover - stripped
            _INJECTOR_FN = lambda: None  # noqa: E731
        else:
            _INJECTOR_FN = active_injector
    return _INJECTOR_FN()


def _guards_enabled() -> bool:
    # lazy: repro.core imports this module transitively at package
    # import time, so the reverse edge must resolve at call time only
    global _GUARDS_ENABLED_FN
    if _GUARDS_ENABLED_FN is None:
        from repro.core import guards
        _GUARDS_ENABLED_FN = guards.enabled
    return _GUARDS_ENABLED_FN()


_TRACER_FN = None
_METRICS_FN = None


def _obs_tracer():
    # same cached-hook pattern as the fault injector: one global read
    # plus a call per guarded seam call; returns None when telemetry
    # is disabled, and the caller takes the pre-telemetry path
    global _TRACER_FN
    if _TRACER_FN is None:
        from repro.obs.spans import active_tracer
        _TRACER_FN = active_tracer
    return _TRACER_FN()


def _obs_metrics():
    global _METRICS_FN
    if _METRICS_FN is None:
        from repro.obs.metrics import metrics
        _METRICS_FN = metrics
    return _METRICS_FN()


def _postcheck(seam: str, args, kwargs, out) -> None:
    """Cheap seam-contract postconditions (O(n) vectorized compares).
    A violation is *actionable* here -- the caller downgrades to the
    next backend -- unlike the warn-or-raise guards in core.guards."""
    if seam == "intersect_keys":
        a, b = args[0], args[1]
        arr = np.asarray(out)
        if len(arr) != len(a):
            raise SeamPostconditionError(
                f"intersect_keys returned {len(arr)} positions for "
                f"{len(a)} keys")
        if len(arr) and (int(arr.max()) >= len(b) or int(arr.min()) < -1):
            raise SeamPostconditionError(
                "intersect_keys position out of range")
    elif seam == "lookup_keys":
        hay, probes = args[0], args[1]
        arr = np.asarray(out)
        if len(arr) != len(probes):
            raise SeamPostconditionError(
                f"lookup_keys returned {len(arr)} positions for "
                f"{len(probes)} probes")
        if len(arr) and (int(arr.max()) >= len(hay) or int(arr.min()) < -1):
            raise SeamPostconditionError("lookup_keys position out of range")
    elif seam == "union_keys":
        u, pa, pb = out
        u = np.asarray(u)
        if len(u) > 1 and bool((np.diff(u) <= 0).any()):
            raise SeamPostconditionError("union_keys output not "
                                         "strictly sorted")
        if len(pa) != len(u) or len(pb) != len(u):
            raise SeamPostconditionError("union_keys position length "
                                         "mismatch")
    elif seam == "union_k_keys":
        u, pos_list = out
        u = np.asarray(u)
        if len(u) > 1 and bool((np.diff(u) <= 0).any()):
            raise SeamPostconditionError("union_k_keys output not "
                                         "strictly sorted")
        if any(len(p) != len(u) for p in pos_list):
            raise SeamPostconditionError("union_k_keys position length "
                                         "mismatch")
    elif seam == "segmented_reduce":
        starts = args[1]
        arr = np.asarray(out)
        if len(arr) != len(starts):
            raise SeamPostconditionError(
                f"segmented_reduce returned {len(arr)} groups for "
                f"{len(starts)} starts")
        semiring = kwargs.get("semiring",
                              args[2] if len(args) > 2 else None)
        arithmetic = semiring is None or semiring.add_vec is np.add
        if arr.dtype.kind == "f" and len(arr):
            with np.errstate(invalid="ignore"):
                if arithmetic:
                    # inf is as illegal as NaN under plain addition
                    bad = not bool(np.isfinite(arr).all())
                else:
                    # tropical semirings use inf legitimately (the
                    # additive identity of min-plus) -- but NaN never is
                    bad = bool(np.isnan(arr).any())
            if bad:
                raise SeamPostconditionError(
                    "segmented_reduce produced "
                    + ("non-finite values under an arithmetic semiring"
                       if arithmetic else "NaN values"))


class GuardedKernels:
    """Degradation-chain wrapper around the kernel-backend registry.

    Exposes the same five seam methods as the raw backends; each call
    walks the chain from the primary backend rightwards until a
    lowering succeeds, with

    * transient faults retried on the same backend with capped
      exponential backoff (``max_retries`` / ``backoff_base`` /
      ``backoff_cap``; ``sleep`` is injectable for tests),
    * permanent faults downgrading to the next backend,
    * a seam x backend pair demoted for the rest of the process after
      ``demote_after`` permanent failures,
    * seam postconditions (when ``REPRO_GUARDS`` != off) converting a
      *corrupted* output into a downgrade as well,
    * every action recorded as a :class:`DowngradeEvent` -- drained by
      the executor via :meth:`pop_events` onto ``SimResult.report``.

    The terminal numpy lowering has no further fallback: if it fails
    too, :class:`KernelChainExhausted` propagates to the executor,
    whose per-Einsum isolation falls back to the interpreter oracle."""

    def __init__(self, primary: str = "numpy", *,
                 max_retries: int = 2, backoff_base: float = 0.05,
                 backoff_cap: float = 1.0, demote_after: int = 3,
                 sleep=time.sleep):
        if isinstance(primary, str):
            if primary not in KERNEL_BACKENDS:
                raise ValueError(
                    f"unknown kernel backend {primary!r}; choose from "
                    f"{KERNEL_BACKENDS}")
            start = DEGRADATION_CHAIN.index(primary)
            self._chain: Tuple = DEGRADATION_CHAIN[start:]
            self.name = primary
        else:
            # a raw backend instance: guard it with the numpy oracle as
            # the only fallback
            self._chain = (primary, "numpy")
            self.name = getattr(primary, "name", type(primary).__name__)
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.demote_after = demote_after
        self._sleep = sleep
        self._unavailable: Dict[str, str] = {}
        self._events: List[DowngradeEvent] = []
        self._lock = threading.Lock()
        #: the Einsum currently executing on the owning backend; set by
        #: ``VectorBackend`` around ``_run`` so DowngradeEvents and seam
        #: spans carry their Einsum attribution
        self.current_einsum = ""
        # hot-path precomputation: (entry, name) pairs so _call does
        # not re-derive names per seam call, and a per-wrapper instance
        # cache so resolved entries skip the registry dict walk
        self._chain_info: Tuple = tuple(
            (e, e if isinstance(e, str)
             else getattr(e, "name", type(e).__name__))
            for e in self._chain)
        self._inst_cache: Dict[str, object] = {}

    # -------------------------------------------------------------- #
    @property
    def chain_names(self) -> Tuple[str, ...]:
        return tuple(b if isinstance(b, str)
                     else getattr(b, "name", type(b).__name__)
                     for b in self._chain)

    def pop_events(self) -> List[DowngradeEvent]:
        """Drain the events recorded since the last drain."""
        with self._lock:
            out, self._events = self._events, []
        return out

    def _record(self, ev: DowngradeEvent) -> None:
        global _EVENTS_RECORDED
        if ev.ts_us == 0.0:
            ev = replace(ev, ts_us=time.perf_counter() * 1e6,
                         einsum=ev.einsum or self.current_einsum)
        with self._lock:
            self._events.append(ev)
        with _GUARD_LOCK:
            _EVENTS_RECORDED += 1
        # rare-event telemetry: counters always, trace instant only
        # when a tracer is installed
        _obs_metrics().counter("kernel.downgrade/" + ev.action).inc()
        tr = _obs_tracer()
        if tr is not None:
            tr.instant("downgrade:" + ev.action, cat="downgrade",
                       args=ev.as_dict())

    # -------------------------------------------------------------- #
    def _instantiate(self, entry, seam: str):
        """The backend instance for a chain entry, or None (recorded as
        unavailable) when it cannot be constructed."""
        if not isinstance(entry, str):
            return entry
        key = entry
        if key in self._unavailable:
            return None
        inst = _INSTANCES.get(key)
        if inst is None:
            try:
                inst = _INSTANCES[key] = _make(key)
            except (ImportError, RuntimeError, OSError, ValueError) as exc:
                reason = f"{type(exc).__name__}: {exc}"
                self._unavailable[key] = reason
                self._record(DowngradeEvent(
                    seam=seam, backend=key,
                    fallback=self._next_name(key),
                    action="unavailable", reason=str(exc),
                    exc_type=type(exc).__name__))
                return None
        return inst

    def _next_name(self, after) -> str:
        names = self.chain_names
        key = after if isinstance(after, str) else getattr(
            after, "name", type(after).__name__)
        try:
            i = names.index(key)
        except ValueError:
            return ""
        return names[i + 1] if i + 1 < len(names) else ""

    # -------------------------------------------------------------- #
    def _call(self, seam: str, *args, **kwargs):
        tr = _obs_tracer()
        if tr is None:
            # disabled path: identical to the pre-telemetry dispatch,
            # no span / histogram objects touched
            return self._dispatch(seam, args, kwargs, None)
        with tr.span("seam:" + seam, cat="seam",
                     args={"einsum": self.current_einsum}
                     if self.current_einsum else None) as sp:
            return self._dispatch(seam, args, kwargs, sp)

    def _dispatch(self, seam: str, args, kwargs, span):
        inj = _active_injector()
        check = _guards_enabled()
        last_exc: Optional[BaseException] = None
        for entry, bname in self._chain_info:
            # lock-free read: set membership is atomic under the GIL
            # and demotions only ever grow the set (writes take the
            # lock in _note_failure)
            if (seam, bname) in _DEMOTED:
                continue
            backend = self._inst_cache.get(bname)
            if backend is None:
                backend = self._instantiate(entry, seam)
                if backend is None:
                    continue
                self._inst_cache[bname] = backend
            attempts = 0
            while True:
                attempts += 1
                try:
                    if inj is not None:
                        inj.before_seam(seam, bname)
                    if span is not None:
                        t0 = time.perf_counter()
                    out = getattr(backend, seam)(*args, **kwargs)
                    if span is not None:
                        _obs_metrics().histogram(
                            f"kernel.seam_seconds/{seam}/{bname}"
                        ).observe(time.perf_counter() - t0)
                        span.set("backend", bname)
                        if attempts > 1:
                            span.set("attempts", attempts)
                    if inj is not None:
                        out = inj.after_seam(seam, bname, out)
                    if check:
                        _postcheck(seam, args, kwargs, out)
                    return out
                except Exception as exc:
                    last_exc = exc
                    if _is_transient(exc) and attempts <= self.max_retries:
                        self._record(DowngradeEvent(
                            seam=seam, backend=bname, fallback="",
                            action="retry", reason=str(exc),
                            exc_type=type(exc).__name__,
                            attempts=attempts))
                        self._sleep(min(
                            self.backoff_base * (2 ** (attempts - 1)),
                            self.backoff_cap))
                        continue
                    self._note_failure(seam, bname, exc, attempts)
                    break
        raise KernelChainExhausted(
            f"all kernel backends failed for seam {seam!r} "
            f"(chain {self.chain_names}); last error: "
            f"{type(last_exc).__name__ if last_exc else '?'}: "
            f"{last_exc}") from last_exc

    def _note_failure(self, seam: str, bname: str,
                      exc: BaseException, attempts: int) -> None:
        fallback = self._next_name(bname)
        self._record(DowngradeEvent(
            seam=seam, backend=bname, fallback=fallback,
            action="downgrade", reason=str(exc),
            exc_type=type(exc).__name__, attempts=attempts))
        with _GUARD_LOCK:
            key = (seam, bname)
            _FAIL_COUNTS[key] = _FAIL_COUNTS.get(key, 0) + 1
            demote = (_FAIL_COUNTS[key] >= self.demote_after
                      and key not in _DEMOTED)
            if demote:
                _DEMOTED.add(key)
        if demote:
            self._record(DowngradeEvent(
                seam=seam, backend=bname, fallback=fallback,
                action="demote",
                reason=f"{_FAIL_COUNTS[key]} failures "
                       f"(threshold {self.demote_after})",
                exc_type=type(exc).__name__, attempts=attempts))

    # -------------------------------------------------------------- #
    # the seam surface (mirrors NumpyKernels)
    # -------------------------------------------------------------- #
    def intersect_keys(self, a, b):
        return self._call("intersect_keys", a, b)

    def union_keys(self, a, b):
        return self._call("union_keys", a, b)

    def union_k_keys(self, arrays):
        return self._call("union_k_keys", arrays)

    def lookup_keys(self, hay, probes):
        return self._call("lookup_keys", hay, probes)

    def segmented_reduce(self, vals, starts, semiring=None,
                         group_ids=None):
        return self._call("segmented_reduce", vals, starts,
                          semiring=semiring, group_ids=group_ids)


def resolve_guarded_kernels(which=None, **opts) -> GuardedKernels:
    """Like :func:`resolve_kernel_backend` but returns the backend
    wrapped in the degradation chain.  Unlike the raw resolver this
    never raises for an unavailable primary (``pallas-tpu`` on a CPU
    host degrades at the first seam call instead): resolution is by
    *name*, instantiation is lazy and guarded."""
    if isinstance(which, GuardedKernels):
        return which
    if which is not None and not isinstance(which, str):
        return GuardedKernels(which, **opts)
    name = which or os.environ.get(ENV_VAR) or "auto"
    if name == "auto":
        name = "pallas-tpu" if _probe_tpu() else "numpy"
    return GuardedKernels(name, **opts)
