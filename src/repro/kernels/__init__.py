"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as <name>.py (pl.pallas_call + explicit BlockSpec
VMEM tiling), with jit'd wrappers in ops.py and pure-jnp oracles in
ref.py.  On this CPU container they execute in interpret mode
(validated by tests/test_kernels.py shape/dtype sweeps); on TPU the
same calls compile to Mosaic.
"""
from repro.kernels import ops, ref
from repro.kernels.block_sparse_matmul import block_sparse_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.intersect import intersect_sorted
from repro.kernels.ssd_chunk import ssd_chunk

__all__ = ["ops", "ref", "block_sparse_matmul", "flash_attention",
           "intersect_sorted", "ssd_chunk"]
