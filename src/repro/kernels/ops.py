"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode -- the
kernel body runs in Python for correctness validation; on TPU the same
calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import block_sparse_matmul as _bsmm
from repro.kernels import flash_attention as _fa
from repro.kernels import intersect as _isect
from repro.kernels import ssd_chunk as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())


def ssd_chunk(x, a, b, c) -> jnp.ndarray:
    return _ssd.ssd_chunk(x, a, b, c, interpret=not _on_tpu())


def intersect_sorted(a, b, block: int = 1024) -> jnp.ndarray:
    return _isect.intersect_sorted(a, b, block=block,
                                   interpret=not _on_tpu())


def pad_sorted(coords: np.ndarray, multiple: int = 1024) -> np.ndarray:
    """Pad a sorted int32 coordinate array with INT32_MAX to a block
    multiple (the kernel's input contract)."""
    n = len(coords)
    n_pad = -(-max(n, 1) // multiple) * multiple
    out = np.full(n_pad, np.iinfo(np.int32).max, np.int32)
    out[:n] = coords
    return out


# ---------------------------------------------------------------------- #
# block-sparse matmul: host-side tile compaction (the SIGMA filter
# cascade S = take(A, B, 0); T = take(A, S, 0) at tile granularity)
# ---------------------------------------------------------------------- #
def compact_tiles(a: np.ndarray, bm: int = 128, bk: int = 128
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compact the nonzero (bm x bk) tiles of ``a``.

    Returns (a_tiles [T, bm, bk], rows [T], cols [T]) sorted by
    (row, col), padded so every tile-row appears at least once (zero
    tile at col 0) -- guaranteeing each output block is initialized.
    """
    a = np.asarray(a)
    m, k = a.shape
    assert m % bm == 0 and k % bk == 0
    nr, nc = m // bm, k // bk
    tiles, rows, cols = [], [], []
    for i in range(nr):
        row_tiles = 0
        for j in range(nc):
            t = a[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk]
            if np.any(t != 0):
                tiles.append(t)
                rows.append(i)
                cols.append(j)
                row_tiles += 1
        if row_tiles == 0:                      # keep output block defined
            tiles.append(np.zeros((bm, bk), a.dtype))
            rows.append(i)
            cols.append(0)
    return (np.stack(tiles), np.asarray(rows, np.int32),
            np.asarray(cols, np.int32))


def block_sparse_matmul(a_tiles, rows, cols, b, m: int,
                        bn: int = 128) -> jnp.ndarray:
    return _bsmm.block_sparse_matmul(a_tiles, rows, cols, b, m=m, bn=bn,
                                     interpret=not _on_tpu())


def block_sparse_matmul_dense_a(a: np.ndarray, b, bm: int = 128,
                                bk: int = 128, bn: int = 128
                                ) -> jnp.ndarray:
    """Convenience: compact a dense-with-zero-tiles A, then multiply."""
    tiles, rows, cols = compact_tiles(np.asarray(a), bm, bk)
    return block_sparse_matmul(tiles, rows, cols, b, m=a.shape[0], bn=bn)
