"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode -- the
kernel body runs in Python for correctness validation; on TPU the same
calls compile to Mosaic.

Also hosts the sorted-coordinate co-iteration primitives used by the
vectorized execution backend (``repro.core.vectorized``): skip-ahead
intersection and merge-path union over *offset-keyed* fibers (many
fibers packed into one globally sorted key array).  The module-level
seam functions (``intersect_keys`` / ``union_k_keys`` / ``lookup_keys``
/ ``segmented_reduce``) dispatch through the pluggable kernel-backend
registry in ``repro.kernels.backends`` -- numpy ``searchsorted``
reference lowerings, jitted XLA programs, or the Pallas kernels
(interpret mode on CPU, Mosaic on TPU) -- selected per process via
``$REPRO_KERNEL_BACKEND`` (see ``backends.resolve_kernel_backend``).
``VectorBackend`` holds its own resolved backend instance and bypasses
these wrappers; they remain the stable entry points for tests and
external callers.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import block_sparse_matmul as _bsmm
from repro.kernels import flash_attention as _fa
from repro.kernels import intersect as _isect
from repro.kernels import ssd_chunk as _ssd

_I32_MAX = np.iinfo(np.int32).max


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())


def ssd_chunk(x, a, b, c) -> jnp.ndarray:
    return _ssd.ssd_chunk(x, a, b, c, interpret=not _on_tpu())


def intersect_sorted(a, b, block: int = 1024) -> jnp.ndarray:
    return _isect.intersect_sorted(a, b, block=block,
                                   interpret=not _on_tpu())


def pad_sorted(coords: np.ndarray, multiple: int = 1024) -> np.ndarray:
    """Pad a sorted int32 coordinate array with INT32_MAX to a block
    multiple (the kernel's input contract)."""
    n = len(coords)
    n_pad = -(-max(n, 1) // multiple) * multiple
    out = np.full(n_pad, np.iinfo(np.int32).max, np.int32)
    out[:n] = coords
    return out


# ---------------------------------------------------------------------- #
# sorted-union / merge kernel (merge-path: one vectorized binary search
# per output slot, the union dual of the skip-ahead intersection kernel)
# ---------------------------------------------------------------------- #
def _merge_kernel(a_ref, b_ref, out_ref, src_ref, *, n: int, m: int,
                  block: int):
    a = a_ref[...]                                     # [n] int32 sorted
    b = b_ref[...]                                     # [m] int32 sorted
    i_blk = pl.program_id(0)
    k = i_blk * block + jnp.arange(block, dtype=jnp.int32)   # output slots

    # merge-path partition: i = #elements taken from a among the first k,
    # found by binary search (ties resolved a-first, i.e. stable merge)
    lo = jnp.maximum(0, k - m)
    hi = jnp.minimum(k, n)
    steps = max(1, (n + m).bit_length())

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        j = k - mid - 1
        av = a[jnp.clip(mid, 0, n - 1)]
        bv = b[jnp.clip(j, 0, m - 1)]
        take_more_a = (mid < n) & (j >= 0) & (av <= bv)
        lo = jnp.where(take_more_a, mid + 1, lo)
        hi = jnp.where(take_more_a, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    i = lo
    j = k - i
    av = a[jnp.clip(i, 0, n - 1)]
    bv = b[jnp.clip(j, 0, m - 1)]
    from_a = (i < n) & ((j >= m) | (av <= bv))
    out_ref[...] = jnp.where(from_a, av, bv)
    src_ref[...] = jnp.where(from_a, 0, 1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def merge_sorted(a: jnp.ndarray, b: jnp.ndarray, block: int = 1024,
                 interpret: bool = False
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable merge of two sorted (PAD-padded) int32 arrays.

    Returns (merged [n+m], src [n+m]) where src is 0 for elements taken
    from ``a`` and 1 for ``b``; on equal values ``a`` comes first."""
    n, = a.shape
    m, = b.shape
    total = n + m
    block = min(block, total)
    grid = (pl.cdiv(total, block),)
    return pl.pallas_call(
        functools.partial(_merge_kernel, n=n, m=m, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((total,), jnp.int32),
                   jax.ShapeDtypeStruct((total,), jnp.int32)],
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------- #
# k-ary multi-merge kernel: each element of each of the k sorted input
# rows finds its global rank in the merged stream with k-1 vectorized
# binary searches (stable: ties resolve by row index).  The union dual
# of stacking pairwise merge-path calls, in one launch.
# ---------------------------------------------------------------------- #
def _multi_merge_kernel(arrs_ref, rank_ref, *, k: int, n: int, block: int):
    a_all = arrs_ref[...]                              # [k, n] int32 sorted
    i = pl.program_id(0)                               # which row
    jb = pl.program_id(1)                              # which block
    e = jax.lax.dynamic_slice(a_all, (i, jb * block), (1, block))[0]
    own = jb * block + jnp.arange(block, dtype=jnp.int32)
    total = own                                        # own stable position
    steps = max(1, n.bit_length())

    for jj in range(k):                                # static unroll over rows
        row = a_all[jj]

        def search(inclusive: bool):
            lo = jnp.zeros(e.shape, jnp.int32)
            hi = jnp.full(e.shape, n, jnp.int32)

            def body(_, carry):
                lo, hi = carry
                mid = (lo + hi) // 2
                rv = row[jnp.clip(mid, 0, n - 1)]
                # freeze once converged (lo == hi) so the fixed-step
                # loop cannot overshoot past n
                go_right = (lo < hi) & (rv <= e if inclusive else rv < e)
                lo = jnp.where(go_right, mid + 1, lo)
                hi = jnp.where(go_right, hi, mid)
                return lo, hi

            lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
            return lo

        cnt_le = search(True)                          # elements <= e
        cnt_lt = search(False)                         # elements <  e
        contrib = jnp.where(jj < i, cnt_le, jnp.where(jj > i, cnt_lt, 0))
        total = total + contrib
    rank_ref[...] = total[None, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def multi_merge_ranks(arrs: jnp.ndarray, block: int = 256,
                      interpret: bool = False) -> jnp.ndarray:
    """arrs: [k, n] int32, each row sorted and PAD-padded.  Returns the
    [k, n] global rank of every element in the stable k-way merge
    (pad ranks are meaningless; callers slice to the real lengths)."""
    k, n = arrs.shape
    block = min(block, n)
    grid = (k, pl.cdiv(n, block))
    return pl.pallas_call(
        functools.partial(_multi_merge_kernel, k=k, n=n, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((k, n), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.int32),
        interpret=interpret,
    )(arrs)


# ---------------------------------------------------------------------- #
# offset-keyed co-iteration primitives (vector backend entry points)
# ---------------------------------------------------------------------- #
def _fits_i32(a: np.ndarray) -> bool:
    return len(a) == 0 or int(a[-1]) < _I32_MAX


def _kb():
    """The process-default kernel backend (env-resolved per call, so
    tests may flip ``$REPRO_KERNEL_BACKEND`` between calls)."""
    from repro.kernels import backends as _backends
    return _backends.resolve_kernel_backend()


def intersect_keys(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Positions in ``b`` of every element of ``a`` (both sorted int64
    key arrays; keys unique per array), -1 where absent.

    Dispatches to the active kernel backend: numpy ``searchsorted``,
    a jitted XLA binary search, or the Pallas skip-ahead intersection
    kernel (int32 key domain)."""
    return _kb().intersect_keys(a, b)


def union_keys(a: np.ndarray, b: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sorted union of two sorted int64 key arrays (keys unique per
    array).  Returns (union, pos_a, pos_b): for every union element its
    position in ``a`` / ``b`` or -1.

    Pallas backends run the merge-path kernel + host dedup."""
    return _kb().union_keys(a, b)


def union_k_keys(arrays) -> Tuple[np.ndarray, list]:
    """Sorted union of k sorted int64 key arrays (keys unique per
    array).  Returns (union, [pos_i]): for every union element its
    position in array i, or -1 where absent.

    k == 2 delegates to ``union_keys``; larger fan-ins run the k-ary
    ``multi_merge_ranks`` Pallas kernel on the pallas backends and a
    concatenate-and-unique ``searchsorted`` lowering on numpy."""
    return _kb().union_k_keys(arrays)


def lookup_keys(hay: np.ndarray, probes: np.ndarray) -> np.ndarray:
    """Gather path for ``Lookup`` IR ops: positions in ``hay`` (sorted
    int64, unique) of every ``probes`` element (arbitrary order,
    duplicates fine), -1 where absent.

    Pallas backends sort the probes, push them through the skip-ahead
    intersection kernel, and unsort; numpy is one vectorized
    ``searchsorted``."""
    return _kb().lookup_keys(hay, probes)


def lookup_keys_shifted(hay: np.ndarray, probes: np.ndarray,
                        shift: int = 0) -> np.ndarray:
    """Affine-shifted gather: positions in ``hay`` of ``probes + shift``,
    -1 where absent.  Negative shifted probes are reported as misses
    *before* dispatch -- a negative coordinate folded into an offset-key
    pack would alias into the preceding fiber's key range.

    The shift folds into the probe stream, so this rides the exact same
    kernel-backend seam as ``lookup_keys``."""
    probes = np.asarray(probes, dtype=np.int64)
    shifted = probes + int(shift)
    neg = shifted < 0
    if neg.any():
        idx = lookup_keys(hay, np.where(neg, 0, shifted))
        return np.where(neg, -1, idx)
    return lookup_keys(hay, shifted)


def intersect_keys_shifted(a: np.ndarray, b: np.ndarray,
                           shift: int = 0) -> np.ndarray:
    """Positions in ``b`` of every element of ``a + shift`` (windowed
    intersection: a constant shift keeps ``a`` sorted, so the shifted
    stream reuses ``intersect_keys``\'s skip-ahead kernel unchanged).
    Negative shifted elements are misses (-1)."""
    a = np.asarray(a, dtype=np.int64)
    shifted = a + int(shift)
    neg = shifted < 0
    if neg.any():
        idx = np.full(len(a), -1, dtype=np.int64)
        idx[~neg] = intersect_keys(shifted[~neg], b)
        return idx
    return intersect_keys(shifted, b)


def segmented_reduce(vals: np.ndarray, starts: np.ndarray,
                     semiring=None,
                     group_ids: Optional[np.ndarray] = None) -> np.ndarray:
    """Semiring-parameterized segmented reduction over a fused-key-sorted
    value stream: ``starts[g]`` is the first index of group ``g``
    (ascending, ``starts[0] == 0``); returns one reduced value per group.
    Values fold strictly left-to-right within each group, bit-identical
    to the interpreter\'s sequential ``semiring.add`` chain (lowering
    notes: ``backends.NumpyKernels.segmented_reduce``)."""
    return _kb().segmented_reduce(vals, starts, semiring,
                                  group_ids=group_ids)


# ---------------------------------------------------------------------- #
# block-sparse matmul: host-side tile compaction (the SIGMA filter
# cascade S = take(A, B, 0); T = take(A, S, 0) at tile granularity)
# ---------------------------------------------------------------------- #
def compact_tiles(a: np.ndarray, bm: int = 128, bk: int = 128
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compact the nonzero (bm x bk) tiles of ``a``.

    Returns (a_tiles [T, bm, bk], rows [T], cols [T]) sorted by
    (row, col), padded so every tile-row appears at least once (zero
    tile at col 0) -- guaranteeing each output block is initialized.
    """
    a = np.asarray(a)
    m, k = a.shape
    assert m % bm == 0 and k % bk == 0
    nr, nc = m // bm, k // bk
    tiles, rows, cols = [], [], []
    for i in range(nr):
        row_tiles = 0
        for j in range(nc):
            t = a[i * bm:(i + 1) * bm, j * bk:(j + 1) * bk]
            if np.any(t != 0):
                tiles.append(t)
                rows.append(i)
                cols.append(j)
                row_tiles += 1
        if row_tiles == 0:                      # keep output block defined
            tiles.append(np.zeros((bm, bk), a.dtype))
            rows.append(i)
            cols.append(0)
    return (np.stack(tiles), np.asarray(rows, np.int32),
            np.asarray(cols, np.int32))


def block_sparse_matmul(a_tiles, rows, cols, b, m: int,
                        bn: int = 128) -> jnp.ndarray:
    return _bsmm.block_sparse_matmul(a_tiles, rows, cols, b, m=m, bn=bn,
                                     interpret=not _on_tpu())


def block_sparse_matmul_dense_a(a: np.ndarray, b, bm: int = 128,
                                bk: int = 128, bn: int = 128
                                ) -> jnp.ndarray:
    """Convenience: compact a dense-with-zero-tiles A, then multiply."""
    tiles, rows, cols = compact_tiles(np.asarray(a), bm, bk)
    return block_sparse_matmul(tiles, rows, cols, b, m=a.shape[0], bn=bn)
