"""Bitmap block-sparse matmul Pallas TPU kernel (SIGMA adapted to TPU).

SIGMA [HPCA'20] fills an irregular PE array with only the nonzero
elements of the stationary matrix via a Benes network; there is no TPU
analogue of element-granular PE filling (the MXU is a rigid 128x128
systolic array).  The TPU-native reading of SIGMA's insight -- *spend
compute only where the stationary operand is nonzero* -- is
tile-granular: a bitmap over (bm x bk) tiles of A (SIGMA's bitmap
format lowered to tile granularity), a compaction of the nonzero tiles
(SIGMA's take()/filter cascade, Fig. 8c), and dense MXU matmuls over
the compacted tile list.

TeAAL view: A's [M, K] ranks are uniform_shape-partitioned to
[M1, K1, M0, K0], the (M1, K1) upper ranks are *flattened* to a single
rank T whose fibertree is compressed (only nonzero tiles are present:
the occupancy form), and T is the sequential loop rank of the mapped
Einsum.  tile_rows/tile_cols are T's coordinate arrays -- exactly the
paper's compressed-fiber (C-format) coordinate storage.

The kernel uses PrefetchScalarGridSpec: the tile coordinate arrays are
scalar-prefetched so BlockSpec index_maps can route each compacted tile
to the right B / Z blocks (the TeAAL 'binding' of T's coordinates to
the address generators).

Grid: (n_nblocks, n_tiles); tiles are sorted by (row, col) so Z blocks
are revisited consecutively, accumulated in the out ref (TPU grids are
serial), initialized on first touch of each (row, nj).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _bsmm_kernel(rows_ref, cols_ref, a_ref, b_ref, z_ref, *, bm: int,
                 bn: int):
    t = pl.program_id(1)

    row = rows_ref[t]
    prev_row = rows_ref[jnp.maximum(t - 1, 0)]
    first = (t == 0) | (row != prev_row)

    @pl.when(first)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    a = a_ref[0].astype(jnp.float32)               # [bm, bk]
    b = b_ref[...].astype(jnp.float32)             # [bk, bn]
    z_ref[...] += jax.lax.dot(a, b,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m", "bn", "interpret"))
def block_sparse_matmul(a_tiles: jnp.ndarray, tile_rows: jnp.ndarray,
                        tile_cols: jnp.ndarray, b: jnp.ndarray,
                        m: int, bn: int = DEFAULT_BN,
                        interpret: bool = False) -> jnp.ndarray:
    """Z[m, n] = sum_t A_tile[t] @ B[cols[t]] scattered to rows[t].

    a_tiles: [T, bm, bk] compacted nonzero tiles sorted by (row, col);
    tile_rows/tile_cols: [T] int32 tile indices; b: [K, N]; ``m`` is the
    number of logical rows of A.  Empty tile lists are padded with
    (row=T-1 sentinel) zero tiles by the caller (``ops.compact_tiles``).
    """
    T, bm, bk = a_tiles.shape
    K, N = b.shape
    bn = min(bn, N)
    n_nb = pl.cdiv(N, bn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_nb, T),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda nj, t, rows, cols: (t, 0, 0)),
            pl.BlockSpec((bk, bn),
                         lambda nj, t, rows, cols: (cols[t], nj)),
        ],
        out_specs=pl.BlockSpec((bm, bn),
                               lambda nj, t, rows, cols: (rows[t], nj)),
    )
    return pl.pallas_call(
        functools.partial(_bsmm_kernel, bm=bm, bn=bn),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, N), jnp.float32),
        interpret=interpret,
    )(tile_rows, tile_cols, a_tiles, b)
