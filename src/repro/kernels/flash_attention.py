"""Blocked (flash) attention Pallas TPU kernel.

TeAAL view (DESIGN.md): the kernel is the mapped Einsum cascade

    S[q, k] = Q[q, d] * K[k, d]
    P[q, k] = softmax_k(S[q, k])          (streaming / online)
    O[q, d] = P[q, k] * V[k, d]

with *uniform_shape* partitioning of Q and KV ranks into VMEM-sized
tiles and loop order [B, H, Q1, K1, (Q0, K0, D)]; the K1 rank is
temporal (sequential) so the online-softmax carry (m, l, acc) lives in
VMEM scratch across K1 steps -- the TPU-idiomatic analogue of Gamma's
merger keeping partial outputs on chip instead of spilling partial
products to HBM.

Grid: (batch, q_heads, nq, nk); the kv block index is innermost so the
accumulator is revisited consecutively (TPU grids execute serially).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, block_q: int,
                 block_k: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
    # zero the ragged tail of the last kv block: its contents are
    # padding (p == 0 there, but 0 * garbage-inf would still be NaN)
    kv_valid = (ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)) < kv_len
    v = jnp.where(kv_valid, v, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    span_q = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    span_k = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = span_k < kv_len                          # ragged kv tail
    if causal:
        mask = mask & (span_q >= span_k)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                             # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)             # fully-masked rows
        o_ref[0, 0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [b, h, sq, d]; k, v: [b, hkv, sk, d] with h % hkv == 0.

    GQA is handled by repeating kv heads logically (index_map folds the
    query head onto its kv group), so no materialized repeat.
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, kv_len=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            # (m, l, acc) online-softmax carry in VMEM
            pl_scratch((block_q, 1), jnp.float32),
            pl_scratch((block_q, 1), jnp.float32),
            pl_scratch((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out


def pl_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
