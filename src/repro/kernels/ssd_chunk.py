"""Mamba2 SSD intra-chunk Pallas TPU kernel.

The quadratic stage of the SSD cascade (repro.models.ssm.ssd, stage 1):

    G[l, s]  = C[l, n] * B[s, n]                 (chunk-local 'attention')
    Y[l, p]  = (G[l, s] . L[l, s]) * X[s, p]     (masked by causal decay)

where L = exp(segsum(a)) is the lower-triangular decay mask.  One grid
step processes one (batch, head, chunk) cell entirely in VMEM: with
chunk length l=256, state n=128, head dim p=64, the working set is
~0.5 MB -- sized to VMEM, with both matmuls on MXU-aligned shapes.

TeAAL view: the S rank is uniform_shape-partitioned into chunks, the
chunk rank is temporal at this kernel's level (the inter-chunk
recurrence is stage 3 of the cascade, outside the kernel), and (B, H)
are spatial (the grid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)      # [l, p]
    a = a_ref[0, 0, 0].astype(jnp.float32)         # [l]
    b = b_ref[0, 0].astype(jnp.float32)            # [l, n]
    c = c_ref[0, 0].astype(jnp.float32)            # [l, n]
    l = a.shape[0]

    # G[l, s] = C . B^T
    g = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # decay mask L[i, j] = exp(cum_a[i] - cum_a[j]) for j <= i
    cum = jnp.cumsum(a)
    li = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    diff = cum[:, None] - cum[None, :]
    mask = li >= lj
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)

    y = jax.lax.dot(g * decay, x, preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
              c: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    """Intra-chunk SSD outputs.

    x: [B, nc, l, H, P] (pre-multiplied by dt); a: [B, H, nc, l];
    b, c: [B, nc, l, N].  Returns y_diag: [B, nc, l, H, P] (float32).
    """
    B, nc, l, H, P = x.shape
    N = b.shape[-1]
    grid = (B, H, nc)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, P),
                         lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, l), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, l, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, l, N), lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, l, 1, P),
                               lambda bi, hi, ci: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nc, l, H, P), jnp.float32),
        interpret=interpret,
    )(x, a, b, c)
