"""Sorted-coordinate intersection Pallas TPU kernel (ExTensor adapted).

ExTensor's [MICRO'19] skip-ahead intersection unit walks two sorted
coordinate fibers and jumps over non-matching runs in ~1 cycle.  TPUs
have no pointer-chasing unit; the TPU-native equivalent of "skip a run
in O(1)" is a VECTORIZED BINARY SEARCH: each coordinate of fiber A
probes fiber B (VMEM-resident) in ceil(log2 m) fully-parallel steps on
the VPU -- the skip-ahead semantics at lane granularity (DESIGN.md
hardware-adaptation notes).

One grid step intersects one block of A (VMEM) against all of B
(VMEM; fibers at TeAAL tile granularity fit VMEM by construction --
that is what uniform-occupancy partitioning is for).

Inputs are padded to block multiples with INT32_MAX (sorted order is
preserved; pads never match).  Returns, per element of A: the position
of the matching coordinate in B, or -1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAD = jnp.iinfo(jnp.int32).max
DEFAULT_BLOCK = 1024


def _isect_kernel(a_ref, b_ref, idx_ref, *, m: int):
    a = a_ref[...]                                 # [bn] int32
    b = b_ref[...]                                 # [m] int32 sorted

    # vectorized lower-bound binary search over [0, m]: the interval
    # halves per step, so m.bit_length() steps reach length zero
    steps = max(1, m.bit_length())
    lo = jnp.zeros(a.shape, jnp.int32)
    hi = jnp.full(a.shape, m, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) // 2
        bv = b[jnp.clip(mid, 0, m - 1)]
        go_right = bv < a
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, m - 1)
    hit = (b[pos] == a) & (a != PAD)
    idx_ref[...] = jnp.where(hit, pos, -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def intersect_sorted(a: jnp.ndarray, b: jnp.ndarray,
                     block: int = DEFAULT_BLOCK,
                     interpret: bool = False) -> jnp.ndarray:
    """a: [n] int32 sorted (PAD-padded); b: [m] int32 sorted (PAD-padded).

    Returns idx [n] int32: position of a[i] in b, or -1 if absent."""
    n, = a.shape
    m, = b.shape
    block = min(block, n)
    grid = (pl.cdiv(n, block),)
    return pl.pallas_call(
        functools.partial(_isect_kernel, m=m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(a, b)
